//! Cross-layer equalization (paper §4.3; Nagel et al. 2019 "Data-Free
//! Quantization").
//!
//! Exploits the scale-equivariance of (P)ReLU: for a pair of consecutive
//! weighted layers, per-channel factors `s_i = √(r₁ᵢ/r₂ᵢ)` rescale layer 1
//! down and layer 2 up so both see equalized per-channel weight ranges —
//! the fix for per-tensor quantization of depthwise-separable stacks
//! (figs 4.2 → 4.3). The unified [`equalize_model`] API performs BN
//! folding, ReLU6→ReLU replacement, cross-layer scaling and high-bias
//! absorption, matching code block 4.1.

use super::bn_fold::{fold_all_batch_norms, FoldInfo};
use crate::graph::{Graph, Op};

/// Replace every ReLU6 with ReLU in place (code block 4.2); returns the
/// number replaced. §4.3.1: check FP32 accuracy after this — if it drops,
/// skip CLE and use AdaRound instead.
pub fn replace_relu6_with_relu(g: &mut Graph) -> usize {
    let mut count = 0;
    for node in &mut g.nodes {
        if matches!(node.op, Op::Relu6) {
            node.op = Op::Relu;
            count += 1;
        }
    }
    count
}

/// A CLE-eligible pair: weighted layer → (ReLU) → weighted layer, all
/// single-consumer.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ClePair {
    pub first: usize,
    pub second: usize,
}

/// Find equalizable consecutive pairs. Scale equivariance requires the
/// in-between activation to be ReLU (or nothing); ReLU6 breaks it, which is
/// why [`equalize_model`] replaces ReLU6 first.
pub fn find_cle_pairs(g: &Graph) -> Vec<ClePair> {
    let weighted = |idx: usize| {
        matches!(
            g.nodes[idx].op,
            Op::Conv2d { .. } | Op::DepthwiseConv2d { .. }
        )
    };
    let mut pairs = Vec::new();
    for first in 0..g.nodes.len() {
        if !weighted(first) {
            continue;
        }
        // Follow a single-consumer chain through at most one ReLU.
        let mut cur = first;
        loop {
            let cons = g.consumers(cur);
            if cons.len() != 1 {
                break;
            }
            let next = cons[0];
            match g.nodes[next].op {
                Op::Relu => {
                    cur = next;
                    continue;
                }
                _ if weighted(next) => {
                    pairs.push(ClePair {
                        first,
                        second: next,
                    });
                    break;
                }
                _ => break,
            }
        }
    }
    pairs
}

/// Per-output-channel absolute range of a weight tensor.
fn out_channel_ranges(op: &Op) -> Vec<f32> {
    let w = op.weight().expect("weighted op");
    w.channel_min_max(0)
        .iter()
        .map(|(lo, hi)| hi.max(-lo))
        .collect()
}

/// Per-*input*-channel absolute range of the second layer's weights.
fn in_channel_ranges(op: &Op) -> Vec<f32> {
    let w = op.weight().expect("weighted op");
    match op {
        // Depthwise: input channel i is filter i.
        Op::DepthwiseConv2d { .. } => out_channel_ranges(op),
        _ => {
            // Conv/Linear: axis 1.
            w.channel_min_max(1)
                .iter()
                .map(|(lo, hi)| hi.max(-lo))
                .collect()
        }
    }
}

/// Apply the scaling vector: `W1[i]/=s_i, b1[i]/=s_i, W2[:,i]*=s_i`.
fn apply_scaling(g: &mut Graph, pair: &ClePair, s: &[f32]) {
    {
        let op = &mut g.nodes[pair.first].op;
        let w = op.weight_mut().unwrap();
        let o = w.dim(0);
        let inner = w.len() / o;
        let wd = w.data_mut();
        for (i, &si) in s.iter().enumerate().take(o) {
            for v in &mut wd[i * inner..(i + 1) * inner] {
                *v /= si;
            }
        }
        let b = op.bias_mut().unwrap();
        for (i, &si) in s.iter().enumerate().take(o) {
            b[i] /= si;
        }
    }
    {
        let op = &mut g.nodes[pair.second].op;
        let is_dw = matches!(op, Op::DepthwiseConv2d { .. });
        let w = op.weight_mut().unwrap();
        if is_dw {
            let c = w.dim(0);
            let inner = w.len() / c;
            let wd = w.data_mut();
            for (i, &si) in s.iter().enumerate().take(c) {
                for v in &mut wd[i * inner..(i + 1) * inner] {
                    *v *= si;
                }
            }
        } else {
            let (o, c) = (w.dim(0), w.dim(1));
            let inner = w.len() / (o * c);
            let wd = w.data_mut();
            for oi in 0..o {
                for (i, &si) in s.iter().enumerate().take(c) {
                    let base = (oi * c + i) * inner;
                    for v in &mut wd[base..base + inner] {
                        *v *= si;
                    }
                }
            }
        }
    }
}

/// Apply an explicit scaling vector to a CLE pair (`W1/=s, b1/=s, W2*=s`).
///
/// Public for the experiment harness: applying *inverse* CLE scales to a
/// trained model synthesizes exactly the per-channel range disparity the
/// paper's fig 4.2 shows on MobileNetV2 — function-preserving (ReLU scale
/// equivariance) yet catastrophic for per-tensor weight quantization.
pub fn scale_pair(g: &mut Graph, pair: &ClePair, s: &[f32]) {
    apply_scaling(g, pair, s);
}

/// Inverse CLE over every depthwise-led pair: cycle `pattern` across the
/// channels as the scale vector (`W_dw/=s`, `W_pw*=s`). Function-preserving
/// (ReLU equivariance) but catastrophic for per-tensor weight quantization —
/// the controlled way to synthesize the fig 4.2 disparity on any
/// BN-folded, ReLU-only model. Returns the number of pairs rescaled.
pub fn unequalize_depthwise(g: &mut Graph, pattern: &[f32]) -> usize {
    assert!(!pattern.is_empty());
    let pairs = find_cle_pairs(g);
    let mut count = 0;
    for pair in &pairs {
        let node = &g.nodes[pair.first];
        if !matches!(node.op, Op::DepthwiseConv2d { .. }) {
            continue;
        }
        let c = node.op.out_channels().unwrap();
        let s: Vec<f32> = (0..c).map(|ci| pattern[ci % pattern.len()]).collect();
        apply_scaling(g, pair, &s);
        count += 1;
    }
    count
}

/// Equalize one pair; returns the applied scale vector.
pub fn equalize_pair(g: &mut Graph, pair: &ClePair) -> Vec<f32> {
    let r1 = out_channel_ranges(&g.nodes[pair.first].op);
    let r2 = in_channel_ranges(&g.nodes[pair.second].op);
    assert_eq!(
        r1.len(),
        r2.len(),
        "CLE pair channel mismatch {} -> {}",
        g.nodes[pair.first].name,
        g.nodes[pair.second].name
    );
    let s: Vec<f32> = r1
        .iter()
        .zip(&r2)
        .map(|(&a, &b)| {
            if a < 1e-12 || b < 1e-12 {
                1.0
            } else {
                (a / b).sqrt()
            }
        })
        .collect();
    apply_scaling(g, pair, &s);
    s
}

/// Cross-layer scaling over all pairs, iterated to convergence (DFQ
/// alternates over pairs until scales stop moving).
pub fn cross_layer_scale(g: &mut Graph, passes: usize) -> usize {
    let pairs = find_cle_pairs(g);
    for _ in 0..passes {
        let mut max_dev = 0.0f32;
        for pair in &pairs {
            let s = equalize_pair(g, pair);
            for &si in &s {
                max_dev = max_dev.max((si - 1.0).abs());
            }
        }
        if max_dev < 1e-3 {
            break;
        }
    }
    pairs.len()
}

/// High-bias absorption (§4.3 step 4): channels whose post-BN distribution
/// sits high (`c_i = max(0, β_i − 3γ_i) > 0`) shift that excess through the
/// ReLU into the next layer's bias: `b1 −= c`, `b2 += W2·c`.
pub fn absorb_high_bias(g: &mut Graph, fold_info: &FoldInfo, scales: &ScaleLog) -> usize {
    let pairs = find_cle_pairs(g);
    let mut absorbed = 0usize;
    for pair in &pairs {
        // Only valid through a ReLU (x > c region must be identity).
        let cons = g.consumers(pair.first);
        if cons.len() != 1 || !matches!(g.nodes[cons[0]].op, Op::Relu) {
            continue;
        }
        let layer1 = g.nodes[pair.first].name.clone();
        let Some(bn) = fold_info.for_layer(&layer1) else {
            continue;
        };
        let s = scales.for_layer(&layer1);
        let c: Vec<f32> = {
            let b1 = g.nodes[pair.first].op.bias().unwrap();
            bn.gamma
                .iter()
                .zip(&bn.var)
                .enumerate()
                .map(|(i, (&gam, &var))| {
                    // Effective post-CLE std of the folded output.
                    let _ = var;
                    let sigma_eff = gam.abs() / s.get(i).copied().unwrap_or(1.0);
                    (b1[i] - 3.0 * sigma_eff).max(0.0)
                })
                .collect()
        };
        if c.iter().all(|&v| v == 0.0) {
            continue;
        }
        absorbed += c.iter().filter(|&&v| v > 0.0).count();
        // b1 -= c
        {
            let b1 = g.nodes[pair.first].op.bias_mut().unwrap();
            for (bv, &cv) in b1.iter_mut().zip(&c) {
                *bv -= cv;
            }
        }
        // b2 += W2 · c (sum over spatial taps).
        {
            let op = &mut g.nodes[pair.second].op;
            let is_dw = matches!(op, Op::DepthwiseConv2d { .. });
            let w = op.weight().unwrap().clone();
            let b2 = op.bias_mut().unwrap();
            if is_dw {
                let ch = w.dim(0);
                let inner = w.len() / ch;
                for i in 0..ch {
                    let tap_sum: f32 = w.data()[i * inner..(i + 1) * inner].iter().sum();
                    b2[i] += tap_sum * c[i];
                }
            } else {
                let (o, ci) = (w.dim(0), w.dim(1));
                let inner = w.len() / (o * ci);
                for oi in 0..o {
                    let mut acc = 0.0f32;
                    for (i, &cv) in c.iter().enumerate().take(ci) {
                        let base = (oi * ci + i) * inner;
                        acc += cv * w.data()[base..base + inner].iter().sum::<f32>();
                    }
                    b2[oi] += acc;
                }
            }
        }
    }
    absorbed
}

/// Cumulative per-layer CLE scales (needed by high-bias absorption to
/// rescale the folded BN σ).
#[derive(Debug, Clone, Default)]
pub struct ScaleLog {
    entries: Vec<(String, Vec<f32>)>,
}

impl ScaleLog {
    pub fn record(&mut self, layer: &str, s: &[f32]) {
        if let Some((_, acc)) = self.entries.iter_mut().find(|(n, _)| n == layer) {
            for (a, &b) in acc.iter_mut().zip(s) {
                *a *= b;
            }
        } else {
            self.entries.push((layer.to_string(), s.to_vec()));
        }
    }

    pub fn for_layer(&self, layer: &str) -> Vec<f32> {
        self.entries
            .iter()
            .find(|(n, _)| n == layer)
            .map(|(_, s)| s.clone())
            .unwrap_or_default()
    }
}

/// The unified `equalize_model` API (code block 4.1): BN folding →
/// ReLU6→ReLU → cross-layer scaling → high-bias absorption. Returns the
/// fold info for downstream analytic bias correction.
pub fn equalize_model(g: &mut Graph) -> FoldInfo {
    let info = fold_all_batch_norms(g);
    replace_relu6_with_relu(g);
    // Scaling with a log so absorption can adjust BN sigmas.
    let pairs = find_cle_pairs(g);
    let mut log = ScaleLog::default();
    for _ in 0..3 {
        for pair in &pairs {
            let name = g.nodes[pair.first].name.clone();
            let s = equalize_pair(g, pair);
            log.record(&name, &s);
        }
    }
    absorb_high_bias(g, &info, &log);
    info
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::tensor::Tensor;
    use crate::visualize::ChannelRanges;

    #[test]
    fn relu6_replacement_counts() {
        let mut g = crate::zoo::build("mobimini", 1).unwrap();
        assert_eq!(replace_relu6_with_relu(&mut g), 7);
        assert_eq!(replace_relu6_with_relu(&mut g), 0);
    }

    #[test]
    fn pairs_found_in_mobimini_after_fold() {
        let mut g = crate::zoo::build("mobimini", 1).unwrap();
        fold_all_batch_norms(&mut g);
        replace_relu6_with_relu(&mut g);
        let pairs = find_cle_pairs(&g);
        // stem→b1.dw, b1.dw→b1.pw, b1.pw→b2.dw, b2.dw→b2.pw, b2.pw→b3.dw,
        // b3.dw→b3.pw (fc is Linear, excluded as second).
        assert_eq!(pairs.len(), 6, "{pairs:?}");
    }

    #[test]
    fn equalization_preserves_function_through_relu() {
        let mut g = crate::zoo::build("mobimini", 2).unwrap();
        fold_all_batch_norms(&mut g);
        replace_relu6_with_relu(&mut g);
        let before = g.clone();
        cross_layer_scale(&mut g, 3);
        let mut rng = Rng::new(5);
        let x = Tensor::randn(&mut rng, &[2, 3, 32, 32], 1.0);
        let ya = before.forward(&x);
        let yb = g.forward(&x);
        let rel = ya.max_abs_diff(&yb) / ya.abs_max().max(1e-6);
        assert!(rel < 1e-3, "rel diff {rel}");
    }

    #[test]
    fn equalization_flattens_channel_ranges() {
        // The fig 4.2 → fig 4.3 effect.
        let mut g = crate::zoo::build("mobimini", 3).unwrap();
        fold_all_batch_norms(&mut g);
        replace_relu6_with_relu(&mut g);
        let dw = g.find("b1.dw").unwrap();
        let spread_before =
            ChannelRanges::of("dw", g.nodes[dw].op.weight().unwrap()).spread();
        cross_layer_scale(&mut g, 3);
        let spread_after =
            ChannelRanges::of("dw", g.nodes[dw].op.weight().unwrap()).spread();
        assert!(
            spread_after < 0.4 * spread_before,
            "spread {spread_before} -> {spread_after}"
        );
    }

    #[test]
    fn equalize_model_unified_api_preserves_function() {
        let g0 = crate::zoo::build("mobimini", 4).unwrap();
        // Reference: folded + relu6->relu (the function equalize_model
        // preserves is the *post-replacement* one — §4.3.1's caveat).
        let mut reference = g0.clone();
        fold_all_batch_norms(&mut reference);
        replace_relu6_with_relu(&mut reference);
        let mut g = g0;
        let info = equalize_model(&mut g);
        assert!(!info.folded.is_empty());
        let mut rng = Rng::new(6);
        let x = Tensor::randn(&mut rng, &[2, 3, 32, 32], 1.0);
        let ya = reference.forward(&x);
        let yb = g.forward(&x);
        let rel = ya.max_abs_diff(&yb) / ya.abs_max().max(1e-6);
        // High-bias absorption is exact only where pre-activations stay
        // above the absorbed offset; allow a small tolerance.
        assert!(rel < 0.05, "rel diff {rel}");
    }

    #[test]
    fn cle_improves_per_tensor_weight_quantization() {
        // The headline claim: after CLE, per-tensor W8 error drops.
        use crate::quantsim::{QuantParams, QuantizationSimModel};
        let g0 = crate::zoo::build("mobimini", 7).unwrap();
        let mut plain = g0.clone();
        fold_all_batch_norms(&mut plain);
        replace_relu6_with_relu(&mut plain);
        let mut equalized = plain.clone();
        cross_layer_scale(&mut equalized, 3);

        let ds = crate::data::SynthImageNet::new(1);
        let batches: Vec<_> = (0..2).map(|i| ds.batch(i, 8).0).collect();
        let (x, _) = ds.batch(10, 8);
        let y_fp = plain.forward(&x);

        let err = |graph: &Graph| -> f32 {
            let mut sim =
                QuantizationSimModel::with_defaults(graph.clone(), QuantParams::default());
            sim.compute_encodings(&batches);
            sim.set_all_act_enabled(false); // isolate weight error
            sim.forward(&x).sq_err(&y_fp)
        };
        let e_plain = err(&plain);
        let e_cle = err(&equalized);
        assert!(
            e_cle < 0.5 * e_plain,
            "CLE {e_cle} !<< plain {e_plain}"
        );
    }
}
