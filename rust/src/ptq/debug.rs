//! The PTQ debugging flow (paper §4.8, fig 4.5).
//!
//! Not an algorithm but a diagnosis procedure: when the standard pipeline
//! leaves the quantized model short of the FP32 baseline, these steps
//! localize the damage — FP32 sanity check, weights-vs-activations split,
//! then a per-quantizer sensitivity sweep — and emit actionable advice
//! ("apply CLE", "try SQNR range setting", "raise this quantizer's
//! bit-width", "fall back to QAT").

use crate::quantsim::QuantizationSimModel;

/// One per-quantizer sensitivity measurement: the metric with *only* this
/// quantizer at target bit-width and everything else at FP32 (the inner
/// for-loop of fig 4.5).
#[derive(Debug, Clone)]
pub struct SensitivityEntry {
    pub name: String,
    /// `"act"` or `"param"`.
    pub kind: &'static str,
    pub metric: f32,
    /// Metric drop vs the FP32 baseline (positive = this quantizer hurts).
    pub drop: f32,
}

/// Full debug-flow report.
#[derive(Debug, Clone)]
pub struct DebugReport {
    /// The caller's FP32 baseline metric.
    pub fp32_metric: f32,
    /// Step 1 — all quantizers bypassed: must match `fp32_metric`.
    pub sanity_metric: f32,
    /// Everything quantized (the starting point of the flow).
    pub full_quant_metric: f32,
    /// Step 2 — only weights quantized.
    pub weights_only_metric: f32,
    /// Step 2 — only activations quantized.
    pub acts_only_metric: f32,
    /// Step 3 — per-quantizer sweep, sorted worst-first.
    pub sensitivity: Vec<SensitivityEntry>,
    /// Derived guidance.
    pub advice: Vec<String>,
}

impl DebugReport {
    /// Render as the flow-chart-shaped text report the CLI prints.
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "FP32 baseline          : {:8.3}\n\
             sanity (all bypassed)  : {:8.3}\n\
             full quantization      : {:8.3}\n\
             weights-only quantized : {:8.3}\n\
             acts-only quantized    : {:8.3}\n",
            self.fp32_metric,
            self.sanity_metric,
            self.full_quant_metric,
            self.weights_only_metric,
            self.acts_only_metric
        ));
        s.push_str("per-quantizer sensitivity (worst 10):\n");
        for e in self.sensitivity.iter().take(10) {
            s.push_str(&format!(
                "  {:5} {:24} metric {:8.3} (drop {:+.3})\n",
                e.kind, e.name, e.metric, e.drop
            ));
        }
        for a in &self.advice {
            s.push_str(&format!("advice: {a}\n"));
        }
        s
    }
}

/// Run the fig 4.5 debugging flow. `eval` maps a sim to the task metric
/// (higher = better, e.g. top-1); the sweep clones the sim per toggle so
/// the caller's sim is untouched.
pub fn run_debug_flow(
    sim: &QuantizationSimModel,
    fp32_metric: f32,
    eval: &dyn Fn(&QuantizationSimModel) -> f32,
) -> DebugReport {
    // Step 1 — FP32 sanity check: bypass everything.
    let mut bypass = sim.clone();
    bypass.set_all_act_enabled(false);
    bypass.set_all_param_enabled(false);
    let sanity_metric = eval(&bypass);

    let full_quant_metric = eval(sim);

    // Step 2 — weights or activations?
    let mut weights_only = sim.clone();
    weights_only.set_all_act_enabled(false);
    let weights_only_metric = eval(&weights_only);

    let mut acts_only = sim.clone();
    acts_only.set_all_param_enabled(false);
    let acts_only_metric = eval(&acts_only);

    // Step 3 — per-quantizer sweep: enable exactly one quantizer at a
    // time on top of the all-bypassed model.
    let mut sensitivity = Vec::new();
    for (idx, node) in sim.graph.nodes.iter().enumerate() {
        if sim.acts[idx].placed && sim.acts[idx].quantizer.is_some() {
            let mut probe = bypass.clone();
            probe.acts[idx].enabled = true;
            let metric = eval(&probe);
            sensitivity.push(SensitivityEntry {
                name: node.name.clone(),
                kind: "act",
                metric,
                drop: fp32_metric - metric,
            });
        }
        if sim.params[idx].as_ref().is_some_and(|s| s.quantizer.is_some()) {
            let mut probe = bypass.clone();
            probe.params[idx].as_mut().unwrap().enabled = true;
            let metric = eval(&probe);
            sensitivity.push(SensitivityEntry {
                name: node.name.clone(),
                kind: "param",
                metric,
                drop: fp32_metric - metric,
            });
        }
    }
    sensitivity.sort_by(|a, b| b.drop.partial_cmp(&a.drop).unwrap());

    // Advice per the flow chart.
    let mut advice = Vec::new();
    let tol = (fp32_metric.abs() * 0.02).max(1e-3);
    if (sanity_metric - fp32_metric).abs() > tol {
        advice.push(
            "sanity check FAILED: bypassed sim deviates from FP32 — inspect the \
             simulation pipeline itself before quantization"
                .to_string(),
        );
    }
    let w_drop = fp32_metric - weights_only_metric;
    let a_drop = fp32_metric - acts_only_metric;
    if w_drop > tol {
        advice.push(
            "weight quantization hurts: apply CLE (depthwise-separable layers), \
             bias correction, or AdaRound; consider per-channel weights"
                .to_string(),
        );
    }
    if a_drop > tol {
        advice.push(
            "activation quantization hurts: try SQNR range setting or re-balance \
             CLE for activation ranges"
                .to_string(),
        );
    }
    if let Some(worst) = sensitivity.first() {
        if worst.drop > tol {
            advice.push(format!(
                "most sensitive quantizer: {} ({}) — consider custom range \
                 setting or a higher bit-width there",
                worst.name, worst.kind
            ));
        }
    }
    if w_drop <= tol && a_drop <= tol && fp32_metric - full_quant_metric > tol {
        advice.push(
            "individual quantizers look fine but the combination hurts — \
             consider quantization-aware training (chapter 5)"
                .to_string(),
        );
    }
    if advice.is_empty() {
        advice.push("quantized model is within tolerance of FP32 — ship it".to_string());
    }

    DebugReport {
        fp32_metric,
        sanity_metric,
        full_quant_metric,
        weights_only_metric,
        acts_only_metric,
        sensitivity,
        advice,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthImageNet;
    use crate::metrics::top1_accuracy;
    use crate::quantsim::QuantParams;
    use crate::zoo;

    fn setup(bw: u32) -> (QuantizationSimModel, f32, Vec<crate::tensor::Tensor>, Vec<usize>) {
        let g = zoo::build("mobimini", 70).unwrap();
        let ds = SynthImageNet::new(71);
        let calib: Vec<_> = (0..3).map(|i| ds.batch(i, 8).0).collect();
        let (x, labels) = ds.batch(10, 16);
        let fp32_metric = top1_accuracy(&g.forward(&x), &labels);
        let mut sim = QuantizationSimModel::with_defaults(
            g,
            QuantParams {
                act_bw: bw,
                param_bw: bw,
                ..Default::default()
            },
        );
        sim.compute_encodings(&calib);
        (sim, fp32_metric, vec![x], labels)
    }

    #[test]
    fn sanity_check_passes_for_bypassed_sim() {
        let (sim, fp32, xs, labels) = setup(8);
        let report = run_debug_flow(&sim, fp32, &|s| {
            top1_accuracy(&s.forward(&xs[0]), &labels)
        });
        assert_eq!(report.sanity_metric, report.fp32_metric);
    }

    #[test]
    fn sweep_covers_every_placed_quantizer() {
        let (sim, fp32, xs, labels) = setup(8);
        let report = run_debug_flow(&sim, fp32, &|s| {
            top1_accuracy(&s.forward(&xs[0]), &labels)
        });
        let (na, np) = sim.quantizer_counts();
        // Input-slot quantizer is not swept per-node; node sweeps only.
        assert_eq!(report.sensitivity.len(), na - 1 + np);
        // Sorted worst-first.
        for w in report.sensitivity.windows(2) {
            assert!(w[0].drop >= w[1].drop);
        }
    }

    #[test]
    fn low_bitwidth_generates_advice() {
        let (sim, fp32, xs, labels) = setup(3);
        let report = run_debug_flow(&sim, fp32, &|s| {
            top1_accuracy(&s.forward(&xs[0]), &labels)
        });
        assert!(!report.advice.is_empty());
        assert!(report.render().contains("advice:"));
    }
}
