//! AdaRound — adaptive rounding for post-training quantization (paper
//! §4.6, code block 4.5; Nagel et al. 2020).
//!
//! Round-to-nearest is not the rounding that minimizes the *task* loss.
//! AdaRound learns, per weight, whether to round **up or down** by
//! optimizing a local per-layer reconstruction loss over a small unlabeled
//! calibration set:
//!
//! ```text
//!   argmin_V ‖ W·x − W̃(V)·x ‖²_F + λ · f_reg(V)
//!   W̃(V)    = s · clamp( ⌊W/s⌋ + h(V), int_min, int_max )
//!   h(V)    = clip( σ(V)·(ζ−γ) + γ, 0, 1 )        (rectified sigmoid)
//!   f_reg   = Σ_ij 1 − |2·h(V_ij) − 1|^β           (β annealed 20 → 2)
//! ```
//!
//! After optimization every `h` has been pushed to {0, 1} by the annealed
//! regularizer and the weight is committed to the chosen grid point. The
//! adarounded weights **assume the encoding grid they were optimized on**,
//! which is why the caller must freeze the returned parameter encodings in
//! any subsequent [`QuantizationSimModel`]
//! (`set_and_freeze_param_encodings`, usage note of code block 4.5).

use crate::graph::{Graph, Input, Op};
use crate::quant::{
    per_channel_weight_encodings, weight_encoding, Encoding, Quantizer,
};
use crate::quantsim::{QuantParams, SimConfig};
use crate::tensor::{im2col, matmul_a_bt, matmul_at_b, Tensor};
use std::collections::BTreeMap;

/// Rectified-sigmoid stretch limits (Nagel et al. 2020, eq. 23).
const ZETA: f32 = 1.1;
const GAMMA: f32 = -0.1;

/// AdaRound hyperparameters (`AdaroundParameters` in the AIMET API).
/// Defaults mirror the paper's guidance: the *number of iterations* and the
/// amount of calibration data are the influential knobs; `reg_param`,
/// `beta_range` and `warm_start` rarely need changing.
#[derive(Debug, Clone, Copy)]
pub struct AdaroundParameters {
    /// Optimization steps per layer (AIMET default 10 000; our layers are
    /// orders of magnitude smaller, so the default is scaled down — the
    /// loss plateaus well before this on every zoo model).
    pub iterations: usize,
    /// Regularizer weight λ.
    pub reg_param: f32,
    /// β annealing range (start, end) for the rounding regularizer.
    pub beta_range: (f32, f32),
    /// Fraction of iterations with the regularizer disabled (pure
    /// reconstruction warm start).
    pub warm_start: f32,
    /// Adam learning rate on V.
    pub lr: f32,
    /// Cap on reconstruction rows (input patches) kept per layer; rows are
    /// strided-subsampled beyond this to bound the per-iteration matmul.
    pub max_rows: usize,
}

impl Default for AdaroundParameters {
    fn default() -> Self {
        AdaroundParameters {
            iterations: 500,
            reg_param: 0.01,
            beta_range: (20.0, 2.0),
            warm_start: 0.2,
            lr: 1e-2,
            max_rows: 2048,
        }
    }
}

/// Per-layer optimization report.
#[derive(Debug, Clone)]
pub struct AdaroundLayerReport {
    pub layer: String,
    /// Mean-squared reconstruction error of plain round-to-nearest.
    pub mse_rtn: f32,
    /// Reconstruction error after AdaRound (soft, pre-commit).
    pub mse_soft: f32,
    /// Reconstruction error of the committed hard rounding.
    pub mse_hard: f32,
    /// Fraction of weights whose rounding flipped vs round-to-nearest.
    pub flipped: f32,
    pub iterations: usize,
}

/// Output of [`apply_adaround`]: the weight-adjusted model plus the frozen
/// parameter encodings the weights were optimized against (what AIMET
/// writes to the `.encodings` JSON for `set_and_freeze_param_encodings`).
#[derive(Debug, Clone)]
pub struct AdaroundResult {
    pub graph: Graph,
    pub param_encodings: BTreeMap<String, Quantizer>,
    pub reports: Vec<AdaroundLayerReport>,
}

/// Apply AdaRound to every Conv2d / DepthwiseConv2d / Linear layer
/// (`Adaround.apply_adaround` in the AIMET API). `batches` is the small
/// unlabeled calibration set (500–2000 samples in the paper).
///
/// Layers are optimized **sequentially in topological order with
/// asymmetric reconstruction**: layer inputs come from the
/// partially-quantized model (all earlier layers already committed to
/// their adarounded grids) while the reconstruction target is the FP32
/// layer's output on FP32 inputs. Each layer therefore also absorbs the
/// accumulated upstream quantization drift — without this, per-layer
/// optimization that wins locally can lose end-to-end (Nagel et al. 2020,
/// §6; AIMET does the same).
pub fn apply_adaround(
    g: &Graph,
    qp: QuantParams,
    cfg: &SimConfig,
    batches: &[Tensor],
    params: &AdaroundParameters,
) -> AdaroundResult {
    adaround_with(g, qp, cfg, batches, params, |_| Some(qp.param_bw))
}

/// AdaRound restricted to the layers in `layer_bws`, each optimized on the
/// grid of its *own* weight bit-width (the AMP search adarounds exactly the
/// layers it drops to 4 bits). Unlisted layers keep their FP32 weights in
/// the working graph — the sequential asymmetric reconstruction still sees
/// every committed upstream layer — and get no frozen encoding, so a later
/// `compute_encodings` ranges them normally at the sim's default bit-width.
pub fn apply_adaround_for_layers(
    g: &Graph,
    qp: QuantParams,
    cfg: &SimConfig,
    batches: &[Tensor],
    params: &AdaroundParameters,
    layer_bws: &BTreeMap<String, u32>,
) -> AdaroundResult {
    adaround_with(g, qp, cfg, batches, params, |name| {
        layer_bws.get(name).copied()
    })
}

/// Shared AdaRound driver: `bw_of` decides, per weighted layer, whether to
/// optimize it (`Some(bit-width)`) or leave it untouched (`None`).
fn adaround_with(
    g: &Graph,
    qp: QuantParams,
    cfg: &SimConfig,
    batches: &[Tensor],
    params: &AdaroundParameters,
    bw_of: impl Fn(&str) -> Option<u32>,
) -> AdaroundResult {
    assert!(!batches.is_empty(), "AdaRound requires calibration data");
    let mut out = g.clone();
    let mut encodings = BTreeMap::new();
    let mut reports = Vec::new();

    // FP32 activations per batch (targets), cached once.
    let acts_fp: Vec<Vec<Tensor>> = batches.iter().map(|b| g.forward_all(b)).collect();

    for idx in 0..g.nodes.len() {
        let node = &g.nodes[idx];
        let (weight, per_channel) = match &node.op {
            Op::Conv2d { weight, .. } | Op::Linear { weight, .. } => {
                (weight, cfg.per_channel)
            }
            Op::DepthwiseConv2d { weight, .. } => (weight, cfg.per_channel),
            // LSTM weights stay round-to-nearest (AdaRound targets conv +
            // fully-connected layers, §4.6).
            _ => continue,
        };
        let Some(bw) = bw_of(&node.name) else { continue };

        // The quantization grid this layer is optimized against (derived
        // from the ORIGINAL weights, as AIMET freezes it).
        let encs: Vec<Encoding> = if per_channel {
            per_channel_weight_encodings(weight, qp.scheme, bw, cfg.param_symmetric, 0)
        } else {
            vec![weight_encoding(weight, qp.scheme, bw, cfg.param_symmetric)]
        };

        // Inputs from the partially-quantized model (earlier layers in
        // `out` are already committed to their grids).
        let acts_q: Vec<Vec<Tensor>> = batches.iter().map(|b| out.forward_all(b)).collect();
        let input_of = |b: usize| -> &Tensor {
            match out.nodes[idx].inputs[0] {
                Input::Graph => &batches[b],
                Input::Node(j) => &acts_q[b][j],
            }
        };
        // FP32 target inputs (for the FP32 reconstruction target).
        let input_fp = |b: usize| -> &Tensor {
            match g.nodes[idx].inputs[0] {
                Input::Graph => &batches[b],
                Input::Node(j) => &acts_fp[b][j],
            }
        };

        let problem = build_problem(g, idx, params.max_rows, input_of, batches.len());
        let target_problem = build_problem(g, idx, params.max_rows, input_fp, batches.len());
        let report = optimize_layer(
            &node.name,
            weight,
            out.nodes.len(), // sanity only
            &encs,
            &problem,
            &target_problem,
            params,
        );
        // Commit the hard-rounded weight into the working graph.
        *out.nodes[idx].op.weight_mut().unwrap() = report.1;
        reports.push(report.0);

        let q = if per_channel {
            Quantizer::per_channel(encs, 0)
        } else {
            Quantizer::per_tensor(encs[0])
        };
        encodings.insert(node.name.clone(), q);
    }

    AdaroundResult {
        graph: out,
        param_encodings: encodings,
        reports,
    }
}

/// A layer's linearized reconstruction problem. For Conv2d and Linear the
/// layer is one matmul `Y[R,O] = X[R,F] · W[O,F]ᵀ`; for DepthwiseConv2d it
/// is one independent problem per channel (each output channel sees only
/// its own `kh·kw` patch columns).
struct Problem {
    /// Per-group (X columns, rows×feat). One group for conv/linear; C
    /// groups for depthwise.
    groups: Vec<Tensor>,
    /// Weight rows covered by each group (start, end).
    row_span: Vec<(usize, usize)>,
}

fn build_problem<'a>(
    g: &Graph,
    idx: usize,
    max_rows: usize,
    input_of: impl Fn(usize) -> &'a Tensor,
    n_batches: usize,
) -> Problem {
    let node = &g.nodes[idx];
    match &node.op {
        Op::Conv2d { weight, spec, .. } => {
            let (kh, kw) = (weight.dim(2), weight.dim(3));
            // im2col emits [F, R]; the optimizer wants rows = locations.
            let cols: Vec<Tensor> = (0..n_batches)
                .map(|b| im2col(input_of(b), kh, kw, *spec).transpose2())
                .collect();
            let x = stack_rows(&cols, max_rows);
            let o = weight.dim(0);
            Problem {
                groups: vec![x],
                row_span: vec![(0, o)],
            }
        }
        Op::Linear { weight, .. } => {
            let f = weight.dim(1);
            let cols: Vec<Tensor> = (0..n_batches)
                .map(|b| {
                    let x = input_of(b);
                    let lead: usize = x.len() / f;
                    x.reshape(&[lead, f])
                })
                .collect();
            let x = stack_rows(&cols, max_rows);
            let o = weight.dim(0);
            Problem {
                groups: vec![x],
                row_span: vec![(0, o)],
            }
        }
        Op::DepthwiseConv2d { weight, spec, .. } => {
            let (c, kh, kw) = (weight.dim(0), weight.dim(2), weight.dim(3));
            let kk = kh * kw;
            let cols: Vec<Tensor> = (0..n_batches)
                .map(|b| im2col(input_of(b), kh, kw, *spec).transpose2())
                .collect();
            let full = stack_rows(&cols, max_rows);
            let rows = full.dim(0);
            // Split the [R, C·kh·kw] patch matrix into C per-channel
            // [R, kh·kw] groups.
            let mut groups = Vec::with_capacity(c);
            let mut row_span = Vec::with_capacity(c);
            for ci in 0..c {
                let mut data = Vec::with_capacity(rows * kk);
                for r in 0..rows {
                    let base = r * c * kk + ci * kk;
                    data.extend_from_slice(&full.data()[base..base + kk]);
                }
                groups.push(Tensor::new(&[rows, kk], data));
                row_span.push((ci, ci + 1));
            }
            Problem { groups, row_span }
        }
        _ => unreachable!("non-weighted layer in build_problem"),
    }
}

/// Vertically concatenate row matrices, strided-subsampling to `max_rows`.
fn stack_rows(parts: &[Tensor], max_rows: usize) -> Tensor {
    let f = parts[0].dim(1);
    let total: usize = parts.iter().map(|p| p.dim(0)).sum();
    let keep = total.min(max_rows);
    let stride = (total as f32 / keep as f32).max(1.0);
    let mut data = Vec::with_capacity(keep * f);
    let mut wanted = 0.0f32;
    let mut seen = 0usize;
    let mut taken = 0usize;
    for p in parts {
        for r in 0..p.dim(0) {
            if taken < keep && seen as f32 >= wanted {
                data.extend_from_slice(&p.data()[r * f..(r + 1) * f]);
                taken += 1;
                wanted += stride;
            }
            seen += 1;
        }
    }
    Tensor::new(&[taken, f], data)
}

/// Per-element optimization for one layer. `problem` holds the
/// quantized-model inputs X̂; `target_problem` the FP32 inputs X (same
/// deterministic row sampling, so rows correspond). The reconstruction is
/// asymmetric: argmin ‖W·X − W̃(V)·X̂‖². Returns the report and the
/// committed (hard-rounded, on-grid) weight.
fn optimize_layer(
    name: &str,
    weight: &Tensor,
    _n_nodes: usize,
    encs: &[Encoding],
    problem: &Problem,
    target_problem: &Problem,
    params: &AdaroundParameters,
) -> (AdaroundLayerReport, Tensor) {
    let w_shape = weight.shape().to_vec();
    let o = w_shape[0];
    let feat: usize = w_shape[1..].iter().product();
    let wd = weight.data();

    // Per-row encoding lookup (per-tensor ⇒ one encoding for all rows).
    let enc_of = |row: usize| -> &Encoding {
        if encs.len() == 1 {
            &encs[0]
        } else {
            &encs[row]
        }
    };

    // Grid decomposition of each weight: w = s·(floor + r), r ∈ [0,1).
    let mut floor_int = vec![0.0f32; o * feat];
    let mut v = vec![0.0f32; o * feat]; // rounding logits
    let mut lo = vec![0.0f32; o * feat];
    let mut hi = vec![0.0f32; o * feat];
    for row in 0..o {
        let e = enc_of(row);
        let (gl, gh) = (
            (e.int_min - e.offset) as f32,
            (e.int_max - e.offset) as f32,
        );
        for j in 0..feat {
            let i = row * feat + j;
            let t = wd[i] / e.scale;
            let f = t.floor();
            let r = (t - f).clamp(1e-4, 1.0 - 1e-4);
            floor_int[i] = f;
            // σ(v)·(ζ−γ)+γ = r  ⇒  v = −ln((ζ−γ)/(r−γ) − 1)
            v[i] = -(((ZETA - GAMMA) / (r - GAMMA) - 1.0).ln());
            lo[i] = gl;
            hi[i] = gh;
        }
    }

    // Reconstruction target per group: Y = X_fp32 · W_fp32ᵀ.
    let targets: Vec<Tensor> = target_problem
        .groups
        .iter()
        .zip(&target_problem.row_span)
        .map(|(x, &(r0, r1))| {
            let wsub = Tensor::new(
                &[r1 - r0, feat],
                wd[r0 * feat..r1 * feat].to_vec(),
            );
            matmul_a_bt(x, &wsub)
        })
        .collect();

    // RTN baseline error.
    let mut w_rtn = vec![0.0f32; o * feat];
    for row in 0..o {
        let e = enc_of(row);
        for j in 0..feat {
            let i = row * feat + j;
            let q = (wd[i] / e.scale).round().clamp(lo[i], hi[i]);
            w_rtn[i] = q * e.scale;
        }
    }
    let mse_rtn = problem_mse(problem, &targets, &w_rtn, feat);

    // Adam state.
    let mut m = vec![0.0f32; o * feat];
    let mut s2 = vec![0.0f32; o * feat];
    let (b1, b2, eps) = (0.9f32, 0.999f32, 1e-8f32);
    let warm = (params.iterations as f32 * params.warm_start) as usize;
    let anneal_len = (params.iterations - warm).max(1) as f32;

    let mut h = vec![0.0f32; o * feat];
    let mut w_soft = vec![0.0f32; o * feat];
    let mut grad = vec![0.0f32; o * feat];
    let mut mse_soft = mse_rtn;

    for it in 0..params.iterations {
        // h(V) and the soft-quantized weight.
        for row in 0..o {
            let e = enc_of(row);
            for j in 0..feat {
                let i = row * feat + j;
                let sg = 1.0 / (1.0 + (-v[i]).exp());
                let hr = sg * (ZETA - GAMMA) + GAMMA;
                h[i] = hr.clamp(0.0, 1.0);
                let q = (floor_int[i] + h[i]).clamp(lo[i], hi[i]);
                w_soft[i] = q * e.scale;
            }
        }

        // Reconstruction gradient dL/dW_soft (MSE over all group outputs).
        grad.iter_mut().for_each(|g| *g = 0.0);
        let mut recon = 0.0f64;
        let mut count = 0usize;
        for (gi, x) in problem.groups.iter().enumerate() {
            let (r0, r1) = problem.row_span[gi];
            let wsub = Tensor::new(
                &[r1 - r0, feat],
                w_soft[r0 * feat..r1 * feat].to_vec(),
            );
            let y = matmul_a_bt(x, &wsub); // [R, rows]
            let d = y.sub(&targets[gi]);
            recon += d.data().iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>();
            count += d.len();
            // dL/dWsub = 2/N · dᵀ · X  → [rows, feat]
            let gsub = matmul_at_b(&d, x);
            for (k, gv) in gsub.data().iter().enumerate() {
                grad[r0 * feat + k] += 2.0 * gv;
            }
        }
        let inv_n = 1.0 / count.max(1) as f32;
        mse_soft = (recon / count.max(1) as f64) as f32;

        // β-annealed rounding regularizer (cosine, AIMET-style), after the
        // warm start.
        let beta = if it < warm {
            f32::INFINITY
        } else {
            let t = (it - warm) as f32 / anneal_len;
            params.beta_range.1
                + 0.5 * (params.beta_range.0 - params.beta_range.1)
                    * (1.0 + (std::f32::consts::PI * t).cos())
        };

        // Chain rule into V, plus regularizer.
        for row in 0..o {
            let e = enc_of(row);
            for j in 0..feat {
                let i = row * feat + j;
                let mut gh = grad[i] * inv_n * e.scale;
                // Clamp gates.
                let pre = floor_int[i] + h[i];
                if pre <= lo[i] || pre >= hi[i] {
                    gh = 0.0;
                }
                if it >= warm && h[i] > 0.0 && h[i] < 1.0 {
                    // d/dh [1 − |2h−1|^β] = −2β·|2h−1|^{β−1}·sign(2h−1)
                    let u = 2.0 * h[i] - 1.0;
                    let du = -2.0 * beta * u.abs().powf(beta - 1.0) * u.signum();
                    gh += params.reg_param * du;
                }
                // dh/dv (rectified sigmoid interior).
                let sg = 1.0 / (1.0 + (-v[i]).exp());
                let hr = sg * (ZETA - GAMMA) + GAMMA;
                let dv = if hr > 0.0 && hr < 1.0 {
                    gh * (ZETA - GAMMA) * sg * (1.0 - sg)
                } else {
                    0.0
                };
                // Adam step.
                m[i] = b1 * m[i] + (1.0 - b1) * dv;
                s2[i] = b2 * s2[i] + (1.0 - b2) * dv * dv;
                let mh = m[i] / (1.0 - b1.powi(it as i32 + 1));
                let sh = s2[i] / (1.0 - b2.powi(it as i32 + 1));
                v[i] -= params.lr * mh / (sh.sqrt() + eps);
            }
        }
    }

    // Commit: h ≥ 0.5 rounds up, else down; write the grid value back as
    // the layer's FP32 weight (RTN on the frozen grid then reproduces it).
    let mut flipped = 0usize;
    let mut w_hard = vec![0.0f32; o * feat];
    for row in 0..o {
        let e = enc_of(row);
        for j in 0..feat {
            let i = row * feat + j;
            let up = if h[i] >= 0.5 { 1.0 } else { 0.0 };
            let q = (floor_int[i] + up).clamp(lo[i], hi[i]);
            w_hard[i] = q * e.scale;
            let rtn_q = (wd[i] / e.scale).round().clamp(lo[i], hi[i]);
            if (q - rtn_q).abs() > 0.5 {
                flipped += 1;
            }
        }
    }
    let mse_hard = problem_mse(problem, &targets, &w_hard, feat);
    let committed = Tensor::new(&w_shape, w_hard);

    (
        AdaroundLayerReport {
            layer: name.to_string(),
            mse_rtn,
            mse_soft,
            mse_hard,
            flipped: flipped as f32 / (o * feat) as f32,
            iterations: params.iterations,
        },
        committed,
    )
}

fn problem_mse(problem: &Problem, targets: &[Tensor], w: &[f32], feat: usize) -> f32 {
    let mut err = 0.0f64;
    let mut count = 0usize;
    for (gi, x) in problem.groups.iter().enumerate() {
        let (r0, r1) = problem.row_span[gi];
        let wsub = Tensor::new(&[r1 - r0, feat], w[r0 * feat..r1 * feat].to_vec());
        let y = matmul_a_bt(x, &wsub);
        err += y.sq_err(&targets[gi]) as f64;
        count += y.len();
    }
    (err / count.max(1) as f64) as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthImageNet;
    use crate::rng::Rng;
    use crate::tensor::Conv2dSpec;
    use crate::zoo;

    fn quick_params() -> AdaroundParameters {
        AdaroundParameters {
            iterations: 120,
            max_rows: 256,
            ..Default::default()
        }
    }

    fn calib(n: usize) -> Vec<Tensor> {
        let ds = SynthImageNet::new(77);
        (0..n).map(|i| ds.batch(i as u64, 4).0).collect()
    }

    #[test]
    fn adaround_beats_rtn_reconstruction() {
        let g = zoo::build("mobimini", 21).unwrap();
        let res = apply_adaround(
            &g,
            QuantParams {
                param_bw: 4,
                ..Default::default()
            },
            &SimConfig::default(),
            &calib(2),
            &quick_params(),
        );
        assert!(!res.reports.is_empty());
        for r in &res.reports {
            assert!(
                r.mse_hard <= r.mse_rtn * 1.02,
                "{}: hard {} !<= rtn {}",
                r.layer,
                r.mse_hard,
                r.mse_rtn
            );
        }
        // At 4 bits at least one layer should improve decisively.
        let best = res
            .reports
            .iter()
            .map(|r| r.mse_hard / r.mse_rtn.max(1e-20))
            .fold(f32::INFINITY, f32::min);
        assert!(best < 0.9, "best ratio {best}");
    }

    #[test]
    fn adarounded_weights_lie_on_the_frozen_grid() {
        let g = zoo::build("mobimini", 22).unwrap();
        let qp = QuantParams::default();
        let res = apply_adaround(&g, qp, &SimConfig::default(), &calib(1), &quick_params());
        for (idx, node) in res.graph.nodes.iter().enumerate() {
            let Some(w) = node.op.weight() else { continue };
            if matches!(node.op, Op::Lstm { .. }) {
                continue;
            }
            let q = &res.param_encodings[&g.nodes[idx].name];
            // qdq on the frozen grid must be exact identity on the
            // committed weights.
            let round_trip = q.qdq(w);
            assert!(
                round_trip.max_abs_diff(w) < 1e-5,
                "{} not on grid",
                node.name
            );
        }
    }

    #[test]
    fn rounding_decisions_actually_flip_somewhere() {
        let g = zoo::build("detmini", 23).unwrap();
        let ds = crate::data::SynthDet::new(5);
        let batches: Vec<Tensor> = (0..2).map(|i| ds.batch(i, 4).0).collect();
        let res = apply_adaround(
            &g,
            QuantParams {
                param_bw: 4,
                ..Default::default()
            },
            &SimConfig::default(),
            &batches,
            &quick_params(),
        );
        let total_flipped: f32 = res.reports.iter().map(|r| r.flipped).sum();
        assert!(total_flipped > 0.0, "AdaRound degenerated to RTN");
    }

    #[test]
    fn depthwise_groups_isolate_channels() {
        // A depthwise layer where channel 0 has huge weights and channel 1
        // tiny ones: the groups must not mix.
        let mut rng = Rng::new(3);
        let mut g = Graph::new();
        let mut w = Tensor::randn(&mut rng, &[2, 1, 3, 3], 1.0);
        for v in &mut w.data_mut()[9..18] {
            *v *= 0.01;
        }
        g.push(
            "dw",
            Op::DepthwiseConv2d {
                weight: w,
                bias: vec![0.0; 2],
                spec: Conv2dSpec::same(3),
            },
        );
        let x = Tensor::randn(&mut rng, &[2, 2, 8, 8], 1.0);
        let res = apply_adaround(
            &g,
            QuantParams::default(),
            &SimConfig::default(),
            &[x],
            &quick_params(),
        );
        assert_eq!(res.reports.len(), 1);
        assert!(res.reports[0].mse_hard <= res.reports[0].mse_rtn * 1.02);
    }

    #[test]
    fn stack_rows_subsamples_deterministically() {
        let a = Tensor::new(&[4, 2], (0..8).map(|v| v as f32).collect());
        let b = Tensor::new(&[4, 2], (8..16).map(|v| v as f32).collect());
        let s = stack_rows(&[&a, &b].map(|t| t.clone()), 4);
        assert_eq!(s.dim(0), 4);
        assert_eq!(s.dim(1), 2);
        // First row always kept.
        assert_eq!(&s.data()[0..2], &[0.0, 1.0]);
        let s2 = stack_rows(&[a, b], 100);
        assert_eq!(s2.dim(0), 8);
    }
}
