//! Bias correction (paper §4.5): quantization error is often *biased* —
//! `E[Wx] ≠ E[W̃x]` — especially in depthwise layers with few weights per
//! channel. Correcting the layer bias recovers part of the FP32 accuracy
//! at zero inference cost.
//!
//! Two methods, as in AIMET (code block 4.4):
//! * [`empirical_bias_correction`] — compare per-channel expected outputs
//!   of the quantized vs FP32 model on calibration data.
//! * [`analytic_bias_correction`] — data-free (Nagel et al. 2019): use the
//!   preceding layer's BN statistics to estimate `E[x]` through the ReLU
//!   (clipped-normal moments), then correct by `−ε·E[x]` where `ε` is the
//!   weight quantization error.

use super::bn_fold::FoldInfo;
use crate::graph::{Graph, Input, Op};
use crate::quantsim::QuantizationSimModel;
use crate::tensor::Tensor;

/// Per-channel mean over batch + spatial dims of a node output.
fn channel_means(t: &Tensor) -> Vec<f32> {
    match t.rank() {
        2 => {
            // [N, C] — mean over batch.
            let (n, c) = (t.dim(0), t.dim(1));
            let mut out = vec![0.0f32; c];
            for ni in 0..n {
                for ci in 0..c {
                    out[ci] += t.data()[ni * c + ci];
                }
            }
            out.iter_mut().for_each(|v| *v /= n as f32);
            out
        }
        3 => {
            // [N, T, F] — mean over batch and time.
            let (n, tt, f) = (t.dim(0), t.dim(1), t.dim(2));
            let mut out = vec![0.0f32; f];
            for i in 0..n * tt {
                for fi in 0..f {
                    out[fi] += t.data()[i * f + fi];
                }
            }
            out.iter_mut().for_each(|v| *v /= (n * tt) as f32);
            out
        }
        _ => t.channel_mean(1),
    }
}

/// Average the per-channel means across calibration batches.
fn mean_over_batches(
    outputs: impl Iterator<Item = Vec<f32>>,
) -> Vec<f32> {
    let mut acc: Option<Vec<f32>> = None;
    let mut count = 0usize;
    for m in outputs {
        match &mut acc {
            None => acc = Some(m),
            Some(a) => {
                for (av, &bv) in a.iter_mut().zip(&m) {
                    *av += bv;
                }
            }
        }
        count += 1;
    }
    let mut a = acc.expect("at least one batch");
    a.iter_mut().for_each(|v| *v /= count as f32);
    a
}

/// Empirical bias correction: for each weighted layer (topological order),
/// compare the quantized model's expected pre-activation output to the
/// FP32 model's and absorb the difference into the bias. Layers are
/// corrected sequentially so later layers see already-corrected inputs
/// (`perform_only_empirical_bias_corr = True` behaviour).
pub fn empirical_bias_correction(
    sim: &mut QuantizationSimModel,
    fp32: &Graph,
    batches: &[Tensor],
) -> usize {
    assert!(!batches.is_empty());
    // FP32 reference means, computed once.
    let fp32_means: Vec<Vec<Vec<f32>>> = batches
        .iter()
        .map(|b| fp32.forward_all(b).iter().map(channel_means).collect())
        .collect();
    let weighted: Vec<usize> = sim
        .graph
        .nodes
        .iter()
        .enumerate()
        .filter(|(_, n)| {
            matches!(
                n.op,
                Op::Conv2d { .. } | Op::DepthwiseConv2d { .. } | Op::Linear { .. }
            )
        })
        .map(|(i, _)| i)
        .collect();
    let mut corrected = 0usize;
    for &idx in &weighted {
        // Quantized means with corrections applied so far.
        let q_mean = mean_over_batches(
            batches
                .iter()
                .map(|b| channel_means(&sim.forward_all(b)[idx])),
        );
        let f_mean = mean_over_batches(fp32_means.iter().map(|per| per[idx].clone()));
        let bias = sim.graph.nodes[idx].op.bias_mut().expect("weighted bias");
        for (b, (f, q)) in bias.iter_mut().zip(f_mean.iter().zip(&q_mean)) {
            *b += f - q;
        }
        corrected += 1;
    }
    corrected
}

/// Standard normal pdf.
fn phi(x: f32) -> f32 {
    (-(x * x) / 2.0).exp() / (2.0 * std::f32::consts::PI).sqrt()
}

/// Standard normal cdf via the Abramowitz–Stegun erf approximation
/// (|err| < 1.5e-7 — plenty for a bias estimate).
fn big_phi(x: f32) -> f32 {
    let t = 1.0 / (1.0 + 0.2316419 * x.abs());
    let poly = t
        * (0.319381530
            + t * (-0.356563782 + t * (1.781477937 + t * (-1.821255978 + t * 1.330274429))));
    let tail = phi(x.abs()) * poly;
    if x >= 0.0 {
        1.0 - tail
    } else {
        tail
    }
}

/// `E[ReLU(X)]` for `X ~ N(μ, σ²)`.
pub fn expected_relu(mu: f32, sigma: f32) -> f32 {
    if sigma < 1e-12 {
        return mu.max(0.0);
    }
    let z = mu / sigma;
    mu * big_phi(z) + sigma * phi(z)
}

/// Analytic (data-free) bias correction. Operates on the *unfolded* graph:
/// finds weighted layers whose input comes from a `BatchNorm [→ ReLU]`
/// chain, estimates `E[x]` per input channel from the BN parameters, and
/// corrects `b += −Σ ε·E[x]` where `ε = qdq(W) − W` under the sim's weight
/// encodings. Layers without BN-stat inputs are skipped (AIMET falls back
/// to empirical correction for those).
pub fn analytic_bias_correction(sim: &mut QuantizationSimModel, fold_info: &FoldInfo) -> usize {
    let mut corrected = 0usize;
    for idx in 0..sim.graph.nodes.len() {
        let node = &sim.graph.nodes[idx];
        let is_target = matches!(
            node.op,
            Op::Conv2d { .. } | Op::DepthwiseConv2d { .. } | Op::Linear { .. }
        );
        if !is_target {
            continue;
        }
        // Walk back: input must be ReLU(BN(·)) or BN(·) — possibly folded,
        // in which case the producer layer has FoldInfo.
        let Some(ex) = expected_input_channels(sim, idx, fold_info) else {
            continue;
        };
        // Weight quantization error under current encodings.
        let Some(wq) = sim.quantized_weight(idx) else {
            continue;
        };
        let node = &sim.graph.nodes[idx];
        let w = node.op.weight().unwrap();
        let eps = wq.sub(w);
        let is_dw = matches!(node.op, Op::DepthwiseConv2d { .. });
        let o = eps.dim(0);
        let correction: Vec<f32> = if is_dw {
            let inner = eps.len() / o;
            (0..o)
                .map(|c| -eps.data()[c * inner..(c + 1) * inner].iter().sum::<f32>() * ex[c])
                .collect()
        } else {
            let ci = eps.dim(1);
            let inner = eps.len() / (o * ci);
            (0..o)
                .map(|oi| {
                    let mut acc = 0.0f32;
                    for (i, &e) in ex.iter().enumerate().take(ci) {
                        let base = (oi * ci + i) * inner;
                        acc -= e * eps.data()[base..base + inner].iter().sum::<f32>();
                    }
                    acc
                })
                .collect()
        };
        let bias = sim.graph.nodes[idx].op.bias_mut().unwrap();
        for (b, c) in bias.iter_mut().zip(&correction) {
            *b += c;
        }
        corrected += 1;
    }
    corrected
}

/// E[x] per input channel of node `idx`, derivable when its producer chain
/// is BN[→ReLU] (unfolded) or a folded layer with recorded BN stats
/// [→ReLU].
fn expected_input_channels(
    sim: &QuantizationSimModel,
    idx: usize,
    fold_info: &FoldInfo,
) -> Option<Vec<f32>> {
    let [input] = sim.graph.nodes[idx].inputs[..] else {
        return None;
    };
    let Input::Node(mut p) = input else {
        return None;
    };
    let mut through_relu = false;
    if matches!(sim.graph.nodes[p].op, Op::Relu) {
        through_relu = true;
        let [Input::Node(pp)] = sim.graph.nodes[p].inputs[..] else {
            return None;
        };
        p = pp;
    }
    // Distribution parameters (μ, σ) per channel.
    let (mu, sigma): (Vec<f32>, Vec<f32>) = match &sim.graph.nodes[p].op {
        Op::BatchNorm { gamma, beta, .. } => {
            (beta.clone(), gamma.iter().map(|g| g.abs()).collect())
        }
        _ => {
            let bn = fold_info.for_layer(&sim.graph.nodes[p].name)?;
            (bn.beta.clone(), bn.gamma.iter().map(|g| g.abs()).collect())
        }
    };
    Some(if through_relu {
        mu.iter()
            .zip(&sigma)
            .map(|(&m, &s)| expected_relu(m, s))
            .collect()
    } else {
        mu
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quantsim::{QuantParams, QuantizationSimModel};
    use crate::rng::Rng;

    #[test]
    fn clipped_normal_moments() {
        // E[ReLU(N(0,1))] = 1/sqrt(2π).
        assert!((expected_relu(0.0, 1.0) - 0.39894).abs() < 1e-3);
        // Far-positive mean: identity.
        assert!((expected_relu(10.0, 1.0) - 10.0).abs() < 1e-3);
        // Far-negative mean: 0.
        assert!(expected_relu(-10.0, 1.0) < 1e-3);
        // Monte-Carlo check at (0.5, 2.0).
        let mut rng = Rng::new(1);
        let mc: f32 = (0..200_000)
            .map(|_| (0.5 + 2.0 * rng.normal()).max(0.0))
            .sum::<f32>()
            / 200_000.0;
        assert!((expected_relu(0.5, 2.0) - mc).abs() < 0.02, "{mc}");
    }

    fn make_sim(seed: u64) -> (QuantizationSimModel, Graph, Vec<Tensor>) {
        let g = crate::zoo::build("mobimini", seed).unwrap();
        let fp32 = g.clone();
        let ds = crate::data::SynthImageNet::new(seed);
        let batches: Vec<_> = (0..3).map(|i| ds.batch(i, 8).0).collect();
        let mut sim = QuantizationSimModel::with_defaults(
            g,
            QuantParams {
                param_bw: 4, // low-bit so the biased error is visible
                ..Default::default()
            },
        );
        sim.compute_encodings(&batches);
        (sim, fp32, batches)
    }

    #[test]
    fn empirical_correction_reduces_output_bias() {
        let (mut sim, fp32, batches) = make_sim(1);
        let (x, _) = crate::data::SynthImageNet::new(99).batch(0, 16);
        let y_fp = fp32.forward(&x);
        let bias_of = |y: &Tensor| -> f32 {
            channel_means(&y.sub(&y_fp))
                .iter()
                .map(|v| v.abs())
                .sum::<f32>()
        };
        let before = bias_of(&sim.forward(&x));
        let n = empirical_bias_correction(&mut sim, &fp32, &batches);
        assert_eq!(n, 8);
        let after = bias_of(&sim.forward(&x));
        assert!(after < before, "bias {before} -> {after}");
    }

    #[test]
    fn empirical_correction_reduces_output_mse() {
        let (mut sim, fp32, batches) = make_sim(2);
        let (x, _) = crate::data::SynthImageNet::new(42).batch(1, 16);
        let y_fp = fp32.forward(&x);
        let before = sim.forward(&x).sq_err(&y_fp);
        empirical_bias_correction(&mut sim, &fp32, &batches);
        let after = sim.forward(&x).sq_err(&y_fp);
        assert!(after < before, "mse {before} -> {after}");
    }

    #[test]
    fn analytic_correction_applies_to_bn_preceded_layers() {
        // Unfolded mobimini: b1.dw is preceded by stem.bn -> stem.relu6?
        // Our analytic walk requires Relu (not Relu6), so replace first.
        let mut g = crate::zoo::build("mobimini", 3).unwrap();
        super::super::cle::replace_relu6_with_relu(&mut g);
        let ds = crate::data::SynthImageNet::new(3);
        let batches: Vec<_> = (0..2).map(|i| ds.batch(i, 8).0).collect();
        let mut sim = QuantizationSimModel::with_defaults(
            g,
            QuantParams {
                param_bw: 4,
                ..Default::default()
            },
        );
        sim.compute_encodings(&batches);
        let n = analytic_bias_correction(&mut sim, &FoldInfo::default());
        // dw and pw layers sit behind BN(+ReLU) chains; stem.conv (graph
        // input) and fc (behind GAP) are skipped.
        assert!(n >= 6, "corrected {n}");
    }

    #[test]
    fn analytic_uses_fold_info_after_folding() {
        let mut g = crate::zoo::build("mobimini", 4).unwrap();
        let info = super::super::cle::equalize_model(&mut g);
        let ds = crate::data::SynthImageNet::new(4);
        let batches: Vec<_> = (0..2).map(|i| ds.batch(i, 8).0).collect();
        let mut sim = QuantizationSimModel::with_defaults(
            g,
            QuantParams {
                param_bw: 4,
                ..Default::default()
            },
        );
        sim.compute_encodings(&batches);
        let n = analytic_bias_correction(&mut sim, &info);
        assert!(n >= 6, "corrected {n}");
    }
}
