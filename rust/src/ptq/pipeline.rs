//! The standard PTQ pipeline (paper §4.2, fig 4.1).
//!
//! ```text
//!   FP32 model
//!     → Cross-layer equalization            (recommended; always BN fold)
//!     → Add quantizers                       (QuantizationSimModel)
//!     → Weight range setting                 (SQNR recommended)
//!     → AdaRound                             (if calibration data)
//!     → Bias correction                      (if no data / analytic)
//!     → Activation range setting             (SQNR, needs calibration)
//!     → quantized sim, drop-in for eval
//! ```
//!
//! Every step is optional and independently controllable so the debugging
//! flow (§4.8) and the ablation benches can switch pieces on and off.

use crate::graph::Graph;
use crate::ptq::{
    analytic_bias_correction, apply_adaround, empirical_bias_correction, equalize_model,
    fold_all_batch_norms, set_activation_ranges, set_weight_ranges, AdaroundParameters,
    AdaroundResult, FoldInfo,
};
use crate::quant::QuantScheme;
use crate::quantsim::{set_and_freeze_param_encodings, QuantParams, QuantizationSimModel, SimConfig};
use crate::tensor::Tensor;

/// Bias-correction variant (§4.5).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BiasCorrection {
    None,
    /// Compare quantized vs FP32 activations on calibration data.
    Empirical,
    /// Data-free: clipped-normal moments from BN statistics (DFQ).
    Analytic,
}

/// Pipeline configuration. [`PtqOptions::default`] reproduces the
/// recommended fig 4.1 settings minus AdaRound (which fig 4.1 gates on a
/// calibration set being available — enable it explicitly).
#[derive(Debug, Clone)]
pub struct PtqOptions {
    pub qp: QuantParams,
    pub cfg: SimConfig,
    /// Apply cross-layer equalization (BN fold happens regardless).
    pub use_cle: bool,
    /// Optimize weight rounding with AdaRound.
    pub use_adaround: bool,
    pub adaround: AdaroundParameters,
    pub bias_correction: BiasCorrection,
    /// Scheme for weight range setting (fig 4.1 recommends SQNR, min-max
    /// can win for per-channel).
    pub weight_scheme: QuantScheme,
    /// Scheme for the final activation range setting.
    pub act_scheme: QuantScheme,
}

impl Default for PtqOptions {
    fn default() -> Self {
        PtqOptions {
            qp: QuantParams::default(),
            cfg: SimConfig::default(),
            use_cle: true,
            use_adaround: false,
            adaround: AdaroundParameters::default(),
            bias_correction: BiasCorrection::Empirical,
            weight_scheme: QuantScheme::TfEnhanced,
            act_scheme: QuantScheme::TfEnhanced,
        }
    }
}

/// What the pipeline did, for reports and EXPERIMENTS.md.
#[derive(Debug, Clone)]
pub struct PtqOutcome {
    pub sim: QuantizationSimModel,
    pub fold_info: FoldInfo,
    pub adaround: Option<AdaroundResult>,
    pub corrected_layers: usize,
    /// Human-readable trace of the steps taken.
    pub log: Vec<String>,
}

/// Run the standard PTQ pipeline of fig 4.1 over a pretrained FP32 graph.
/// `calib` is the representative unlabeled calibration set (order of 1000
/// samples in the paper; a few small batches here).
pub fn standard_ptq_pipeline(g: &Graph, calib: &[Tensor], opts: &PtqOptions) -> PtqOutcome {
    assert!(!calib.is_empty(), "PTQ range setting requires calibration data");
    let mut log = Vec::new();
    let mut g = g.clone();

    // 1. CLE (includes BN folding) or plain BN folding (§3.2 recommends
    //    folding before simulation either way).
    let fold_info = if opts.use_cle {
        let info = equalize_model(&mut g);
        log.push(format!(
            "cross-layer equalization (folded {} batch norms)",
            info.folded.len()
        ));
        info
    } else {
        let info = fold_all_batch_norms(&mut g);
        log.push(format!("batch-norm folding ({} folded)", info.folded.len()));
        info
    };

    // FP32 reference for empirical bias correction: the equalized/folded
    // model (numerically ≈ the original FP32 model).
    let fp32_ref = g.clone();

    // 2. AdaRound rewrites the weights before the sim is built; its grid
    //    must then be frozen in the sim (code block 4.5 usage note).
    let adaround = if opts.use_adaround {
        let res = apply_adaround(&g, opts.qp, &opts.cfg, calib, &opts.adaround);
        log.push(format!(
            "adaround over {} layers ({} iterations each)",
            res.reports.len(),
            opts.adaround.iterations
        ));
        g = res.graph.clone();
        Some(res)
    } else {
        None
    };

    // 3. Add quantizers.
    let mut sim = QuantizationSimModel::new(g, opts.cfg.clone(), opts.qp);
    let (na, np) = sim.quantizer_counts();
    log.push(format!("added quantizers ({na} activation, {np} parameter)"));

    if let Some(res) = &adaround {
        set_and_freeze_param_encodings(&mut sim, &res.param_encodings);
        log.push("froze adarounded parameter encodings".to_string());
    }

    // 4. Range setting: weights first, then a calibration pass for
    //    activations (needed before bias correction's quantized forwards).
    sim.compute_encodings(calib);
    set_weight_ranges(&mut sim, opts.weight_scheme);
    set_activation_ranges(&mut sim, calib, opts.act_scheme);
    log.push(format!(
        "range setting (weights {:?}, activations {:?})",
        opts.weight_scheme, opts.act_scheme
    ));

    // 5. Bias correction.
    let corrected_layers = match opts.bias_correction {
        BiasCorrection::None => 0,
        BiasCorrection::Empirical => {
            let n = empirical_bias_correction(&mut sim, &fp32_ref, calib);
            log.push(format!("empirical bias correction ({n} layers)"));
            n
        }
        BiasCorrection::Analytic => {
            let n = analytic_bias_correction(&mut sim, &fold_info);
            log.push(format!("analytic bias correction ({n} layers)"));
            n
        }
    };

    // 6. Final activation range setting over the corrected model (the last
    //    box of fig 4.1) — bias shifts move activation ranges slightly.
    if corrected_layers > 0 {
        set_activation_ranges(&mut sim, calib, opts.act_scheme);
        log.push("re-set activation ranges after bias correction".to_string());
    }

    PtqOutcome {
        sim,
        fold_info,
        adaround,
        corrected_layers,
        log,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthImageNet;
    use crate::metrics::top1_accuracy;
    use crate::zoo;

    fn calib(n: usize) -> Vec<Tensor> {
        let ds = SynthImageNet::new(55);
        (0..n).map(|i| ds.batch(i as u64, 8).0).collect()
    }

    #[test]
    fn pipeline_produces_runnable_sim() {
        let g = zoo::build("mobimini", 60).unwrap();
        let out = standard_ptq_pipeline(&g, &calib(3), &PtqOptions::default());
        assert!(out.log.len() >= 4);
        let (x, labels) = SynthImageNet::new(56).batch(0, 8);
        let acc = top1_accuracy(&out.sim.forward(&x), &labels);
        assert!((0.0..=100.0).contains(&acc));
        // BN folding removed all BatchNorm nodes.
        assert!(out
            .sim
            .graph
            .nodes
            .iter()
            .all(|n| n.op.kind() != "BatchNorm"));
    }

    #[test]
    fn cle_pipeline_beats_no_cle_on_mobimini_output_error() {
        // The Table 4.1 phenomenon at unit scale: per-tensor W8 on a
        // depthwise model with disparate channel ranges is rescued by CLE.
        let mut g = zoo::build("mobimini", 61).unwrap();
        crate::ptq::fold_all_batch_norms(&mut g);
        crate::ptq::replace_relu6_with_relu(&mut g);
        crate::ptq::unequalize_depthwise(&mut g, &[1.0, 16.0, 4.0, 64.0]);
        let data = calib(3);
        let (x, _) = SynthImageNet::new(57).batch(0, 8);
        let y_fp = g.forward(&x);
        let mut no_cle = PtqOptions::default();
        no_cle.use_cle = false;
        no_cle.bias_correction = BiasCorrection::None;
        let mut with_cle = PtqOptions::default();
        with_cle.bias_correction = BiasCorrection::None;
        let e_no = standard_ptq_pipeline(&g, &data, &no_cle)
            .sim
            .forward(&x)
            .sq_err(&y_fp);
        let e_yes = standard_ptq_pipeline(&g, &data, &with_cle)
            .sim
            .forward(&x)
            .sq_err(&y_fp);
        assert!(
            e_yes < 0.7 * e_no,
            "CLE {e_yes} should clearly beat no-CLE {e_no}"
        );
    }

    #[test]
    fn empirical_bc_reduces_output_bias() {
        let g = zoo::build("mobimini", 62).unwrap();
        let data = calib(3);
        let (x, _) = SynthImageNet::new(58).batch(0, 8);
        let y_fp = g.forward(&x);
        let mut no_bc = PtqOptions::default();
        no_bc.bias_correction = BiasCorrection::None;
        let mut bc = PtqOptions::default();
        bc.bias_correction = BiasCorrection::Empirical;
        let mean_shift = |y: &Tensor| -> f32 {
            y.data()
                .iter()
                .zip(y_fp.data())
                .map(|(a, b)| a - b)
                .sum::<f32>()
                .abs()
                / y.len() as f32
        };
        let s_no = mean_shift(&standard_ptq_pipeline(&g, &data, &no_bc).sim.forward(&x));
        let s_bc = mean_shift(&standard_ptq_pipeline(&g, &data, &bc).sim.forward(&x));
        assert!(
            s_bc <= s_no * 1.05,
            "bias correction should not increase output bias ({s_bc} vs {s_no})"
        );
    }

    #[test]
    fn adaround_slot_freezes_encodings() {
        let g = zoo::build("mobimini", 63).unwrap();
        let mut opts = PtqOptions::default();
        opts.use_adaround = true;
        opts.adaround.iterations = 60;
        opts.adaround.max_rows = 128;
        opts.bias_correction = BiasCorrection::None;
        let out = standard_ptq_pipeline(&g, &calib(2), &opts);
        assert!(out.adaround.is_some());
        for slot in out.sim.params.iter().flatten() {
            assert!(slot.frozen, "adarounded params must be frozen");
        }
    }
}
