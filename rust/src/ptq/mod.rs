//! Post-training quantization (paper chapter 4).
//!
//! The PTQ suite takes a pretrained FP32 graph and optimizes its weights
//! and quantization parameters *without fine-tuning*:
//!
//! * [`fold_all_batch_norms`] — batch-normalization folding (§3.2).
//! * [`equalize_model`] — cross-layer equalization + high-bias absorption
//!   (§4.3), including the ReLU6→ReLU caveat helper (§4.3.1).
//! * [`set_weight_ranges`] / [`set_activation_ranges`] — min-max vs SQNR
//!   clipping-threshold choice (§4.4).
//! * [`empirical_bias_correction`] / [`analytic_bias_correction`] (§4.5).
//! * [`apply_adaround`] — adaptive rounding (§4.6).
//! * [`standard_ptq_pipeline`] — the fig 4.1 pipeline tying it together.
//! * [`run_debug_flow`] — the fig 4.5 debugging flow.

mod adaround;
mod bias_correction;
mod bn_fold;
mod cle;
mod debug;
mod pipeline;
mod range_setting;

pub use adaround::{
    apply_adaround, apply_adaround_for_layers, AdaroundLayerReport, AdaroundParameters,
    AdaroundResult,
};
pub use bias_correction::{
    analytic_bias_correction, empirical_bias_correction, expected_relu,
};
pub use bn_fold::{fold_all_batch_norms, FoldInfo, FoldedBn};
pub use cle::{
    absorb_high_bias, cross_layer_scale, equalize_model, equalize_pair, find_cle_pairs,
    replace_relu6_with_relu, scale_pair, unequalize_depthwise, ClePair, ScaleLog,
};
pub use debug::{run_debug_flow, DebugReport, SensitivityEntry};
pub use pipeline::{standard_ptq_pipeline, BiasCorrection, PtqOptions, PtqOutcome};
pub use range_setting::{scheme_mse, set_activation_ranges, set_weight_ranges};
