//! PJRT runtime — loads and executes the AOT-compiled JAX/Pallas programs.
//!
//! `make artifacts` runs `python/compile/aot.py` once: it lowers each L2
//! JAX program (which may call L1 Pallas kernels, interpret-mode) to **HLO
//! text** and writes `artifacts/manifest.json` describing every program's
//! input/output shapes. This module is the L3 side: a
//! [`Runtime`] owns a PJRT CPU client, compiles programs on first use, and
//! executes them with [`Tensor`] inputs — Python never runs again.
//!
//! HLO *text* (not serialized `HloModuleProto`) is the interchange format:
//! jax ≥ 0.5 emits protos with 64-bit instruction ids which xla_extension
//! 0.5.1 rejects; the text parser reassigns ids and round-trips cleanly.

use crate::graph::{Graph, Op};
use crate::json::{parse, Json};
use crate::tensor::Tensor;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::{BTreeMap, HashMap};
use std::path::{Path, PathBuf};

/// One AOT program as described by `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct ProgramSpec {
    pub name: String,
    pub file: String,
    /// Expected input shapes, in parameter order.
    pub inputs: Vec<Vec<usize>>,
    /// Output shapes (the program returns a tuple).
    pub outputs: Vec<Vec<usize>>,
    pub desc: String,
}

/// PJRT runtime over an artifacts directory.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    specs: BTreeMap<String, ProgramSpec>,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Default artifacts directory: `$AIMET_ARTIFACTS`, else
    /// `<workspace>/artifacts`.
    pub fn artifacts_dir() -> PathBuf {
        if let Ok(p) = std::env::var("AIMET_ARTIFACTS") {
            return PathBuf::from(p);
        }
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// Whether a manifest exists at `dir` (lets tests/examples skip
    /// gracefully when `make artifacts` has not been run).
    pub fn available(dir: &Path) -> bool {
        dir.join("manifest.json").is_file()
    }

    /// Open the runtime: create the PJRT CPU client and parse the
    /// manifest. Programs compile lazily on first execution.
    pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading manifest in {} (run `make artifacts`)", dir.display()))?;
        let root = parse(&text).map_err(|e| anyhow!("manifest parse error: {e}"))?;
        let mut specs = BTreeMap::new();
        let programs = root
            .get("programs")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing programs object"))?;
        for (name, p) in programs {
            let shapes = |key: &str| -> Result<Vec<Vec<usize>>> {
                p.get(key)
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("program {name}: missing {key}"))?
                    .iter()
                    .map(|s| {
                        s.as_arr()
                            .ok_or_else(|| anyhow!("program {name}: bad shape"))?
                            .iter()
                            .map(|d| {
                                d.as_f64()
                                    .map(|v| v as usize)
                                    .ok_or_else(|| anyhow!("program {name}: bad dim"))
                            })
                            .collect()
                    })
                    .collect()
            };
            specs.insert(
                name.clone(),
                ProgramSpec {
                    name: name.clone(),
                    file: p
                        .get("file")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("program {name}: missing file"))?
                        .to_string(),
                    inputs: shapes("inputs")?,
                    outputs: shapes("outputs")?,
                    desc: p
                        .get("desc")
                        .and_then(Json::as_str)
                        .unwrap_or("")
                        .to_string(),
                },
            );
        }
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime {
            client,
            dir,
            specs,
            cache: HashMap::new(),
        })
    }

    pub fn programs(&self) -> impl Iterator<Item = &ProgramSpec> {
        self.specs.values()
    }

    pub fn spec(&self, name: &str) -> Option<&ProgramSpec> {
        self.specs.get(name)
    }

    /// Compile (or fetch the cached executable for) one program.
    fn ensure_compiled(&mut self, name: &str) -> Result<()> {
        if self.cache.contains_key(name) {
            return Ok(());
        }
        let spec = self
            .specs
            .get(name)
            .ok_or_else(|| anyhow!("unknown program {name}"))?;
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("loading {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        self.cache.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute a program with `Tensor` inputs; returns the tuple of output
    /// tensors. Shapes are validated against the manifest.
    pub fn execute(&mut self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        self.ensure_compiled(name)?;
        let spec = &self.specs[name];
        if inputs.len() != spec.inputs.len() {
            bail!(
                "program {name}: expected {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            );
        }
        for (i, (t, want)) in inputs.iter().zip(&spec.inputs).enumerate() {
            // Rank-0 manifest entries accept single-element tensors (the
            // Rust Tensor has no rank-0; scalars are shape [1]).
            if want.is_empty() && t.len() == 1 {
                continue;
            }
            if t.shape() != want.as_slice() {
                bail!(
                    "program {name}: input {i} shape {:?} != manifest {:?}",
                    t.shape(),
                    want
                );
            }
        }
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .zip(&spec.inputs)
            .map(|(t, want)| tensor_to_literal(t, want))
            .collect::<Result<_>>()?;
        let exe = &self.cache[name];
        let result = exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {name} result: {e:?}"))?;
        let parts = result
            .to_tuple()
            .map_err(|e| anyhow!("untupling {name} result: {e:?}"))?;
        parts.into_iter().map(literal_to_tensor).collect()
    }
}

fn tensor_to_literal(t: &Tensor, want: &[usize]) -> Result<xla::Literal> {
    // Use the manifest shape (handles rank-0 scalars, which the Rust
    // Tensor represents as shape [1]).
    let dims: Vec<i64> = if want.is_empty() && t.len() == 1 {
        Vec::new()
    } else {
        t.shape().iter().map(|&d| d as i64).collect()
    };
    xla::Literal::vec1(t.data())
        .reshape(&dims)
        .map_err(|e| anyhow!("literal reshape: {e:?}"))
}

fn literal_to_tensor(lit: xla::Literal) -> Result<Tensor> {
    let shape = lit
        .array_shape()
        .map_err(|e| anyhow!("literal shape: {e:?}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = lit
        .to_vec::<f32>()
        .map_err(|e| anyhow!("literal to_vec: {e:?}"))?;
    Ok(if dims.is_empty() {
        Tensor::scalar(data[0])
    } else {
        Tensor::new(&dims, data)
    })
}

/// Canonical flattening of a graph's parameters, mirrored exactly by
/// `python/compile/model.py::param_specs`: for each node in topological
/// order — Conv/DepthwiseConv/Linear contribute `[weight, bias]`,
/// BatchNorm `[gamma, beta, mean, var]`, LSTM `[w_ih, w_hh, bias]`.
pub fn graph_param_tensors(g: &Graph) -> Vec<Tensor> {
    let mut out = Vec::new();
    for node in &g.nodes {
        match &node.op {
            Op::Conv2d { weight, bias, .. }
            | Op::DepthwiseConv2d { weight, bias, .. }
            | Op::Linear { weight, bias } => {
                out.push(weight.clone());
                out.push(Tensor::new(&[bias.len()], bias.clone()));
            }
            Op::BatchNorm {
                gamma,
                beta,
                mean,
                var,
                ..
            } => {
                out.push(Tensor::new(&[gamma.len()], gamma.clone()));
                out.push(Tensor::new(&[beta.len()], beta.clone()));
                out.push(Tensor::new(&[mean.len()], mean.clone()));
                out.push(Tensor::new(&[var.len()], var.clone()));
            }
            Op::Lstm {
                w_ih, w_hh, bias, ..
            } => {
                out.push(w_ih.clone());
                out.push(w_hh.clone());
                out.push(Tensor::new(&[bias.len()], bias.clone()));
            }
            _ => {}
        }
    }
    out
}

/// Inverse of [`graph_param_tensors`]: write a parameter list back into
/// the graph (used by the PJRT training drivers after `*_step` programs
/// return updated weights).
pub fn set_graph_params(g: &mut Graph, params: &[Tensor]) {
    let mut it = params.iter();
    let mut next = || it.next().expect("param list too short");
    for node in &mut g.nodes {
        match &mut node.op {
            Op::Conv2d { weight, bias, .. }
            | Op::DepthwiseConv2d { weight, bias, .. }
            | Op::Linear { weight, bias } => {
                *weight = next().clone();
                *bias = next().data().to_vec();
            }
            Op::BatchNorm {
                gamma,
                beta,
                mean,
                var,
                ..
            } => {
                *gamma = next().data().to_vec();
                *beta = next().data().to_vec();
                *mean = next().data().to_vec();
                *var = next().data().to_vec();
            }
            Op::Lstm {
                w_ih, w_hh, bias, ..
            } => {
                *w_ih = next().clone();
                *w_hh = next().clone();
                *bias = next().data().to_vec();
            }
            _ => {}
        }
    }
    assert!(it.next().is_none(), "param list too long");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::zoo;

    #[test]
    fn param_roundtrip_every_model() {
        for model in zoo::MODEL_NAMES {
            let g = zoo::build(model, 9).unwrap();
            let params = graph_param_tensors(&g);
            assert!(!params.is_empty(), "{model} has no params?");
            let mut g2 = zoo::build(model, 10).unwrap();
            set_graph_params(&mut g2, &params);
            let p2 = graph_param_tensors(&g2);
            assert_eq!(params.len(), p2.len());
            for (a, b) in params.iter().zip(&p2) {
                assert_eq!(a, b, "{model} param mismatch");
            }
        }
    }

    #[test]
    fn available_is_false_for_missing_dir() {
        assert!(!Runtime::available(Path::new("/nonexistent/nowhere")));
    }

    #[test]
    fn manifest_parse_errors_are_reported() {
        let dir = std::env::temp_dir().join("aimet_rt_test_bad");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), "{not json").unwrap();
        assert!(Runtime::open(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
