//! # aimet-rs — Neural Network Quantization Toolkit
//!
//! A from-scratch reproduction of the system described in *"Neural Network
//! Quantization with AI Model Efficiency Toolkit (AIMET)"* (Qualcomm AI
//! Research, 2022) as a three-layer Rust + JAX + Pallas stack.
//!
//! The crate plays the role of AIMET's compiled Model Optimization backend:
//! it owns the model-graph IR, the quantization simulation
//! ([`quantsim::QuantizationSimModel`]), the full post-training-quantization
//! suite ([`ptq`]: batch-norm folding, cross-layer equalization, bias
//! correction, AdaRound, range setting, the standard pipeline and the
//! debugging flow), the model compression suite ([`compress`]: spatial
//! SVD, channel pruning, greedy ratio search, and the composed
//! compress-then-quantize path), quantization-aware training ([`qat`]), the
//! integer-only inference engine and batched serving front-end
//! ([`engine`]: quantsim → lowered `QuantizedModel` with folded
//! requantization, plus micro-batching over the worker pool), synthetic
//! datasets ([`data`]), metrics, and a PJRT runtime ([`runtime`]) that
//! executes JAX/Pallas programs AOT-lowered to HLO text at build time.
//!
//! The integer hot path runs on a runtime-dispatched SIMD kernel tier
//! ([`quant::simd`]: AVX2 / SSE4.1 / NEON / scalar, every variant
//! bit-identical to the scalar reference; `AIMET_FORCE_SCALAR=1` pins
//! the reference tier).
//!
//! Python never runs on the request path: `make artifacts` lowers the L2
//! JAX models (which call the L1 Pallas kernels) once, and everything else
//! is this crate.

// Lints allowed crate-wide so `scripts/ci.sh` can run
// `cargo clippy -- -D warnings`. The first group are genuine kernel/IR
// idioms: dense kernels index with explicit loop bounds (the
// disjoint-write SAFETY arguments read off the indices), lowering passes
// thread many scalar geometry parameters, and the graph/op enums
// intentionally keep large and small variants side by side. The second
// group are style lints the pre-gate codebase was never linted against;
// they are kept allowed to bootstrap the gate and should be tightened
// opportunistically (remove an entry, fix what fires, repeat).
#![allow(
    clippy::too_many_arguments,
    clippy::needless_range_loop,
    clippy::large_enum_variant,
    clippy::type_complexity,
    clippy::manual_memcpy,
    clippy::manual_range_contains,
    clippy::new_without_default,
    clippy::len_without_is_empty
)]
#![allow(
    clippy::collapsible_if,
    clippy::collapsible_else_if,
    clippy::comparison_chain
)]

pub mod compress;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod graph;
pub mod json;
pub mod metrics;
pub mod obs;
pub mod pool;
pub mod ptq;
pub mod qat;
pub mod quant;
pub mod quantsim;
pub mod rng;
pub mod task;
pub mod runtime;
pub mod tensor;
pub mod testutil;
pub mod visualize;
pub mod zoo;

pub use tensor::Tensor;
