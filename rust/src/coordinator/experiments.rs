//! Experiment runners — one per paper table/figure (DESIGN.md §5).
//!
//! Each runner trains the relevant zoo model on its synthetic workload,
//! applies the quantization treatment under test, and returns rows shaped
//! like the paper's table. The benches (`rust/benches/table_*.rs`) and the
//! CLI (`aimet experiment <id>`) both call straight into these functions,
//! so the reproduced numbers in EXPERIMENTS.md are regenerable from either
//! entry point.
//!
//! Acceptance is *shape*, not absolute numbers (DESIGN.md §5): who wins,
//! by roughly what factor, and where the crossovers fall.

use crate::graph::Graph;
use crate::ptq::{
    equalize_model, fold_all_batch_norms, run_debug_flow, standard_ptq_pipeline, BiasCorrection,
    DebugReport, PtqOptions,
};
use crate::qat::{fit_fp32, fit_qat, TrainConfig, TrainLog};
use crate::quant::QuantScheme;
use crate::quantsim::{QuantParams, QuantizationSimModel};
use crate::task::{evaluate_graph, evaluate_sim, TaskData};
use crate::visualize::{weight_ranges, ChannelRanges};
use crate::zoo;

/// Experiment speed preset. `fast` keeps every experiment under ~a minute
/// for CI and `cargo bench`; `full` is the EXPERIMENTS.md configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Effort {
    Fast,
    Full,
}

impl Effort {
    fn train_steps(self) -> usize {
        match self {
            Effort::Fast => 150,
            Effort::Full => 500,
        }
    }
    fn eval_batches(self) -> usize {
        match self {
            Effort::Fast => 4,
            Effort::Full => 12,
        }
    }
    fn calib_batches(self) -> usize {
        match self {
            Effort::Fast => 3,
            Effort::Full => 8,
        }
    }
    fn qat_steps(self) -> usize {
        match self {
            Effort::Fast => 80,
            Effort::Full => 300,
        }
    }
    fn adaround_iters(self) -> usize {
        match self {
            Effort::Fast => 300,
            Effort::Full => 600,
        }
    }
}

const EVAL_BATCH: usize = 16;

/// Train one zoo model to a usable FP32 baseline on its synthetic task.
///
/// For MobiMini the trained model is additionally put into the fig 4.2
/// regime: real MobileNetV2 checkpoints arrive with wildly disparate
/// per-channel depthwise weight ranges (an artifact of training dynamics
/// our short synthetic runs cannot reproduce), so we synthesize that exact
/// pathology with *inverse CLE scales* — a function-preserving
/// re-parameterization (ReLU scale equivariance) that per-tensor weight
/// quantization cannot survive but CLE can undo. DESIGN.md §3 documents
/// the substitution.
pub fn trained_model(model: &str, effort: Effort, seed: u64) -> (Graph, TaskData, TrainLog) {
    trained_model_with(model, effort, seed, None, None)
}

/// [`trained_model`] with explicit step/LR overrides (the CLI's `train
/// --steps/--lr` flags; `None` keeps the per-model defaults below).
pub fn trained_model_with(
    model: &str,
    effort: Effort,
    seed: u64,
    steps_override: Option<usize>,
    lr_override: Option<f32>,
) -> (Graph, TaskData, TrainLog) {
    let mut g = zoo::build(model, seed).unwrap();
    let data = TaskData::new(model, seed + 1).expect("zoo model name");
    // Per-model budgets: the detector's objectness head needs far more
    // steps than the classifiers (1–3 positives per 64 cells), and the
    // recurrent model prefers a hotter LR.
    let (steps, lr) = match (model, effort) {
        ("detmini", Effort::Fast) => (1200, 0.1),
        ("detmini", Effort::Full) => (2500, 0.1),
        ("speechmini", _) => (effort.train_steps(), 0.15),
        _ => (effort.train_steps(), 0.05),
    };
    let steps = steps_override.unwrap_or(steps);
    let lr = lr_override.unwrap_or(lr);
    let cfg = TrainConfig {
        steps,
        lr,
        lr_decay_every: steps / 2,
        ..Default::default()
    };
    let log = fit_fp32(&mut g, model, &data, &cfg);
    if model == "mobimini" {
        seed_cle_pathology(&mut g);
    }
    (g, data, log)
}

/// Inject fig 4.2's per-channel weight-range disparity into a trained
/// MobiMini: fold BNs, replace ReLU6 (→ exact scale equivariance), then
/// push inverse-CLE scales through every depthwise pair.
pub fn seed_cle_pathology(g: &mut Graph) {
    crate::ptq::fold_all_batch_norms(g);
    crate::ptq::replace_relu6_with_relu(g);
    crate::ptq::unequalize_depthwise(g, &[1.0, 32.0, 8.0, 160.0]);
}

// ---------------------------------------------------------------------
// Table 4.1 — PTQ with CLE/BC (W8/A8) vs plain round-to-nearest.
// ---------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct Table41Row {
    pub model: String,
    pub fp32: f32,
    pub rtn_w8a8: f32,
    pub clebc_w8a8: f32,
}

pub fn table_4_1(effort: Effort) -> Vec<Table41Row> {
    ["mobimini", "resmini", "segmini"]
        .iter()
        .map(|&model| {
            let (g, data, _) = trained_model(model, effort, 100);
            let fp32 = evaluate_graph(&g, model, &data, effort.eval_batches(), EVAL_BATCH)
                .expect("zoo eval");
            let calib = data.calibration(effort.calib_batches(), EVAL_BATCH);

            // "W8/A8 without CLE/BC": BN fold + min-max ranges only.
            let rtn_opts = PtqOptions {
                use_cle: false,
                bias_correction: BiasCorrection::None,
                weight_scheme: QuantScheme::Tf,
                act_scheme: QuantScheme::Tf,
                ..Default::default()
            };
            let rtn = standard_ptq_pipeline(&g, &calib, &rtn_opts);
            let rtn_acc = evaluate_sim(&rtn.sim, model, &data, effort.eval_batches(), EVAL_BATCH)
                .expect("zoo eval");

            // "AIMET W8/A8 with CLE/BC" (fig 4.1 defaults).
            let full = standard_ptq_pipeline(&g, &calib, &PtqOptions::default());
            let full_acc = evaluate_sim(&full.sim, model, &data, effort.eval_batches(), EVAL_BATCH)
                .expect("zoo eval");

            Table41Row {
                model: model.to_string(),
                fp32,
                rtn_w8a8: rtn_acc,
                clebc_w8a8: full_acc,
            }
        })
        .collect()
}

pub fn render_table_4_1(rows: &[Table41Row]) -> String {
    let mut s = String::from(
        "Table 4.1 — ImageNet-analog accuracy with AIMET PTQ (CLE + bias correction)\n\
         model      | FP32    | W8/A8 no CLE/BC | W8/A8 CLE/BC\n\
         -----------+---------+-----------------+-------------\n",
    );
    for r in rows {
        s.push_str(&format!(
            "{:<10} | {:6.2}% | {:14.2}% | {:11.2}%\n",
            r.model, r.fp32, r.rtn_w8a8, r.clebc_w8a8
        ));
    }
    s
}

// ---------------------------------------------------------------------
// Table 4.2 — AdaRound vs round-to-nearest on the detection model.
// ---------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct Table42Row {
    pub config: String,
    pub fp32_map: f32,
    pub rtn_map: f32,
    pub adaround_map: f32,
}

pub fn table_4_2(effort: Effort) -> Vec<Table42Row> {
    let model = "detmini";
    let (g, data, _) = trained_model(model, effort, 200);
    let fp32 = evaluate_graph(&g, model, &data, effort.eval_batches(), EVAL_BATCH)
        .expect("zoo eval");
    let calib = data.calibration(effort.calib_batches(), EVAL_BATCH);
    // The paper's ADAS row is W8/A8 on a production model that RTN
    // collapses; our laptop-scale detector is more robust at W8, so the
    // RTN-collapse -> AdaRound-recovery crossover appears at W4/A8 here
    // (consistent with §4.6: AdaRound is what *enables low-bit weight
    // quantization*). Both arms get CLE + bias correction, like the
    // paper's "despite the use of CLE/BC" setup.
    [(8u32, 8u32), (4, 8)]
        .iter()
        .map(|&(w_bw, a_bw)| {
            let qp = QuantParams {
                param_bw: w_bw,
                act_bw: a_bw,
                ..Default::default()
            };
            let rtn_opts = PtqOptions {
                qp,
                ..Default::default()
            };
            let rtn = standard_ptq_pipeline(&g, &calib, &rtn_opts);
            let rtn_map = evaluate_sim(&rtn.sim, model, &data, effort.eval_batches(), EVAL_BATCH)
                .expect("zoo eval");

            let mut ada_opts = PtqOptions {
                qp,
                use_adaround: true,
                ..Default::default()
            };
            ada_opts.adaround.iterations = effort.adaround_iters();
            ada_opts.adaround.max_rows = 2048;
            let ada = standard_ptq_pipeline(&g, &calib, &ada_opts);
            let ada_map = evaluate_sim(&ada.sim, model, &data, effort.eval_batches(), EVAL_BATCH)
                .expect("zoo eval");

            Table42Row {
                config: format!("W{w_bw}/A{a_bw}"),
                fp32_map: fp32,
                rtn_map,
                adaround_map: ada_map,
            }
        })
        .collect()
}

pub fn render_table_4_2(rows: &[Table42Row]) -> String {
    let mut s = String::from(
        "Table 4.2 — ADAS-analog object detection (mAP), round-to-nearest vs AdaRound\n\
         config | FP32    | round-to-nearest | AdaRound\n\
         -------+---------+------------------+---------\n",
    );
    for r in rows {
        s.push_str(&format!(
            "{:<6} | {:6.2}% | {:15.2}% | {:7.2}%\n",
            r.config, r.fp32_map, r.rtn_map, r.adaround_map
        ));
    }
    s
}

// ---------------------------------------------------------------------
// Table 5.1 — QAT vs PTQ (W8/A8, PTQ-initialized QAT).
// ---------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct Table51Row {
    pub model: String,
    pub fp32: f32,
    pub ptq: f32,
    pub qat: f32,
}

pub fn table_5_1(effort: Effort) -> Vec<Table51Row> {
    ["mobimini", "resmini"]
        .iter()
        .map(|&model| {
            let (g, data, _) = trained_model(model, effort, 300);
            let fp32 = evaluate_graph(&g, model, &data, effort.eval_batches(), EVAL_BATCH)
                .expect("zoo eval");
            let calib = data.calibration(effort.calib_batches(), EVAL_BATCH);
            let ptq_out = standard_ptq_pipeline(&g, &calib, &PtqOptions::default());
            let ptq = evaluate_sim(&ptq_out.sim, model, &data, effort.eval_batches(), EVAL_BATCH)
                .expect("zoo eval");

            // Fig 5.2: QAT starts from the PTQ-initialized sim.
            let mut sim = ptq_out.sim.clone();
            let qat_cfg = TrainConfig {
                steps: effort.qat_steps(),
                lr: 0.01,
                lr_decay_every: effort.qat_steps() / 2,
                ..Default::default()
            };
            fit_qat(&mut sim, model, &data, &qat_cfg);
            let qat = evaluate_sim(&sim, model, &data, effort.eval_batches(), EVAL_BATCH)
                .expect("zoo eval");

            Table51Row {
                model: model.to_string(),
                fp32,
                ptq,
                qat,
            }
        })
        .collect()
}

pub fn render_table_5_1(rows: &[Table51Row]) -> String {
    let mut s = String::from(
        "Table 5.1 — QAT results (W8/A8, PTQ-initialized)\n\
         model      | FP32    | AIMET PTQ | AIMET QAT\n\
         -----------+---------+-----------+----------\n",
    );
    for r in rows {
        s.push_str(&format!(
            "{:<10} | {:6.2}% | {:8.2}% | {:8.2}%\n",
            r.model, r.fp32, r.ptq, r.qat
        ));
    }
    s
}

// ---------------------------------------------------------------------
// Table 5.2 — bi-LSTM QAT (token error rate; lower is better).
// ---------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct Table52Row {
    pub fp32_ter: f32,
    pub qat_ter: f32,
}

pub fn table_5_2(effort: Effort) -> Table52Row {
    let model = "speechmini";
    let (g, data, _) = trained_model(model, effort, 400);
    // evaluate_* return 100−TER (higher-better); flip back to TER.
    let fp32_ter = 100.0
        - evaluate_graph(&g, model, &data, effort.eval_batches(), EVAL_BATCH)
            .expect("zoo eval");
    let calib = data.calibration(effort.calib_batches(), EVAL_BATCH);
    let mut sim = QuantizationSimModel::with_defaults(g, QuantParams::default());
    sim.compute_encodings(&calib);
    let qat_cfg = TrainConfig {
        steps: effort.qat_steps(),
        lr: 0.05,
        lr_decay_every: effort.qat_steps() / 2,
        ..Default::default()
    };
    fit_qat(&mut sim, model, &data, &qat_cfg);
    let qat_ter = 100.0
        - evaluate_sim(&sim, model, &data, effort.eval_batches(), EVAL_BATCH)
            .expect("zoo eval");
    Table52Row { fp32_ter, qat_ter }
}

pub fn render_table_5_2(row: &Table52Row) -> String {
    format!(
        "Table 5.2 — DeepSpeech2-analog bi-LSTM QAT (token error rate, lower is better)\n\
         model       | FP32 TER | AIMET QAT TER\n\
         ------------+----------+--------------\n\
         speechmini  | {:7.2}% | {:12.2}%\n",
        row.fp32_ter, row.qat_ter
    )
}

// ---------------------------------------------------------------------
// Figures 4.2 / 4.3 — per-channel weight ranges before/after CLE.
// ---------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct CleRangesResult {
    pub layer: String,
    pub before: ChannelRanges,
    pub after: ChannelRanges,
}

/// Per-channel weight ranges of the first depthwise layer of MobiMini
/// after BN folding, before vs after CLE (the paper's figs 4.2/4.3).
pub fn fig_4_2_4_3(effort: Effort) -> CleRangesResult {
    let (g, _, _) = trained_model("mobimini", effort, 500);
    let mut folded = g.clone();
    fold_all_batch_norms(&mut folded);
    let before = weight_ranges(&folded)
        .into_iter()
        .find(|r| r.layer == "b1.dw")
        .expect("b1.dw ranges");
    let mut equalized = g.clone();
    equalize_model(&mut equalized);
    let after = weight_ranges(&equalized)
        .into_iter()
        .find(|r| r.layer == "b1.dw")
        .expect("b1.dw ranges");
    CleRangesResult {
        layer: "b1.dw".to_string(),
        before,
        after,
    }
}

pub fn render_fig_4_2_4_3(res: &CleRangesResult) -> String {
    format!(
        "Figures 4.2/4.3 — per-channel weight ranges of {} (MobiMini)\n\
         BEFORE CLE (spread {:.1}x):\n{}\n\
         AFTER CLE (spread {:.1}x):\n{}\n",
        res.layer,
        res.before.spread(),
        res.before.to_ascii(60),
        res.after.spread(),
        res.after.to_ascii(60)
    )
}

// ---------------------------------------------------------------------
// Fig 4.5 — the debugging flow on a deliberately hurt model.
// ---------------------------------------------------------------------

pub fn debug_flow_demo(effort: Effort) -> DebugReport {
    debug_flow_for("mobimini", effort)
}

/// The fig-4.5 debugging flow end-to-end on any zoo model (what
/// `aimet debug --model <name>` runs): train, quantize W4/A8 without CLE
/// so the flow has something to diagnose, then walk the decision tree.
pub fn debug_flow_for(model: &str, effort: Effort) -> DebugReport {
    let (g, data, _) = trained_model(model, effort, 600);
    let fp32 = evaluate_graph(&g, model, &data, effort.eval_batches(), EVAL_BATCH)
        .expect("zoo eval");
    let calib = data.calibration(effort.calib_batches(), EVAL_BATCH);
    // A W4/A8 no-CLE sim: broken enough for the flow to say something.
    let opts = PtqOptions {
        qp: QuantParams {
            param_bw: 4,
            ..Default::default()
        },
        use_cle: false,
        bias_correction: BiasCorrection::None,
        ..Default::default()
    };
    let out = standard_ptq_pipeline(&g, &calib, &opts);
    let eval_batches = effort.eval_batches().min(2);
    run_debug_flow(&out.sim, fp32, &|sim| {
        evaluate_sim(sim, model, &data, eval_batches, EVAL_BATCH).expect("zoo eval")
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    // One smoke test per experiment at minimum effort; the benches run
    // the real thing. These are the most expensive unit tests in the
    // crate but they pin the *shape* claims of DESIGN.md §5.

    #[test]
    fn table_4_1_shape_holds() {
        let rows = table_4_1(Effort::Fast);
        assert_eq!(rows.len(), 3);
        let mobi = &rows[0];
        let res = &rows[1];
        // (i) RTN collapses MobiMini but not ResMini;
        assert!(
            mobi.rtn_w8a8 < mobi.fp32 - 10.0,
            "mobimini RTN should collapse: fp32 {} rtn {}",
            mobi.fp32,
            mobi.rtn_w8a8
        );
        assert!(
            res.rtn_w8a8 > res.fp32 - 15.0,
            "resmini RTN should roughly hold: fp32 {} rtn {}",
            res.fp32,
            res.rtn_w8a8
        );
        // (ii) CLE/BC recovers MobiMini most of the way.
        assert!(
            mobi.clebc_w8a8 > mobi.rtn_w8a8 + 5.0,
            "CLE/BC must recover mobimini: rtn {} clebc {}",
            mobi.rtn_w8a8,
            mobi.clebc_w8a8
        );
    }

    #[test]
    fn fig_4_2_4_3_cle_flattens_ranges() {
        let res = fig_4_2_4_3(Effort::Fast);
        assert!(
            res.after.spread() < 0.5 * res.before.spread(),
            "CLE must flatten channel ranges: {} -> {}",
            res.before.spread(),
            res.after.spread()
        );
    }
}
