//! L3 coordinator — the toolkit's command-line frontend and experiment
//! orchestration.
//!
//! The paper's contribution is a *toolkit*, so the coordinator is the
//! AIMET user surface rendered as a CLI: `train`, `ptq`, `qat`, `debug`,
//! `export` are the workflows of chapters 3–5, and `experiment <id>`
//! regenerates each paper table/figure via [`experiments`].

pub mod experiments;

mod cli;

pub use cli::cli_main;
