//! The `aimet` command-line interface.
//!
//! Hand-rolled argument parsing (the offline build carries no clap); every
//! command maps to one paper workflow:
//!
//! ```text
//! aimet models                         list zoo models
//! aimet config                         print the default runtime config JSON
//! aimet train      --model M [...]     FP32 training (loss curve)
//! aimet ptq        --model M [...]     fig 4.1 pipeline + eval report
//! aimet qat        --model M [...]     fig 5.2 pipeline + eval report
//! aimet compress   --model M [...]     greedy SVD/prune search + PTQ compose
//! aimet quantize-amp --model M [...]   greedy W4/W8 per-layer bit-width search
//! aimet infer      --model M [...]     lower to the integer engine + validate vs sim
//! aimet serve-bench --model M [...]    batched int8 serving latency/throughput
//! aimet debug      [--effort E]         fig 4.5 debugging flow
//! aimet export     --model M --out D   train + ptq + export encodings (§3.3)
//! aimet experiment <id>                table4.1|table4.2|table5.1|table5.2|fig4.2|all
//! aimet runtime    [--run NAME]        list / smoke-run PJRT artifacts
//! ```
//!
//! Parsing is strict: each subcommand declares its accepted flags
//! ([`command_spec`]) and anything else — unknown flags, missing values,
//! stray positionals — exits 2 with the valid-flag list.

use super::experiments::{self, Effort};
use crate::compress::{amp_greedy_plan, compress_then_ptq, greedy_plan, AmpOptions, SearchOptions};
use crate::engine::{
    lower, run_serve_bench, run_serve_bench_with, BatchConfig, ServeMonitor, ServeOptions,
};
use crate::obs::{DriftConfig, DriftReport, FaultPlan};
use crate::ptq::{standard_ptq_pipeline, PtqOptions};
use crate::qat::{fit_qat, TrainConfig};
use crate::quantsim::default_config_json;
use crate::runtime::{graph_param_tensors, Runtime};
use crate::task::{evaluate_graph, evaluate_sim, TaskData};
use crate::{metrics, zoo};

/// Strict flag parser: `--key value` pairs after the subcommand, checked
/// against the subcommand's accepted flag list. Unknown flags, flags
/// missing their value, and unexpected positionals are hard errors that
/// name the valid flags — silently ignoring a typo like `--tagret-ratio`
/// would run the wrong experiment.
struct Args {
    flags: std::collections::BTreeMap<String, String>,
    positionals: Vec<String>,
}

/// Flags that are on/off switches: present means `true`, no value is
/// consumed (`aimet infer --profile --trace t.json` parses as expected).
const SWITCH_FLAGS: &[&str] = &["profile"];

impl Args {
    fn parse(rest: &[String], allowed: &[&str], max_positionals: usize) -> Result<Args, String> {
        let valid = || {
            if allowed.is_empty() {
                "this command takes no flags".to_string()
            } else {
                format!(
                    "valid flags: {}",
                    allowed
                        .iter()
                        .map(|f| format!("--{f}"))
                        .collect::<Vec<_>>()
                        .join(" ")
                )
            }
        };
        let mut flags = std::collections::BTreeMap::new();
        let mut positionals = Vec::new();
        let mut i = 0;
        while i < rest.len() {
            if let Some(key) = rest[i].strip_prefix("--") {
                if !allowed.contains(&key) {
                    return Err(format!("unknown flag --{key}; {}", valid()));
                }
                if SWITCH_FLAGS.contains(&key) {
                    flags.insert(key.to_string(), "true".to_string());
                    i += 1;
                    continue;
                }
                match rest.get(i + 1) {
                    Some(v) if !v.starts_with("--") => {
                        flags.insert(key.to_string(), v.clone());
                        i += 2;
                    }
                    _ => return Err(format!("flag --{key} requires a value; {}", valid())),
                }
            } else {
                positionals.push(rest[i].clone());
                if positionals.len() > max_positionals {
                    return Err(format!(
                        "unexpected argument `{}`; {}",
                        rest[i],
                        valid()
                    ));
                }
                i += 1;
            }
        }
        Ok(Args { flags, positionals })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    /// The target zoo model — validated, so a typo'd `--model mobimimi`
    /// errors instead of panicking deep inside `zoo::build(..).unwrap()`.
    fn model(&self) -> Result<String, String> {
        let m = self.get("model").unwrap_or("mobimini");
        if zoo::MODEL_NAMES.contains(&m) {
            Ok(m.to_string())
        } else {
            Err(format!(
                "unknown model `{m}`; valid models: {}",
                zoo::MODEL_NAMES.join(" ")
            ))
        }
    }

    /// Typed flag lookup. A present-but-unparseable value is an error —
    /// falling back to the default would silently run the wrong
    /// configuration, the exact failure the strict parser exists to stop.
    fn parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("flag --{key}: cannot parse value `{v}`")),
        }
    }

    fn usize_or(&self, key: &str, default: usize) -> Result<usize, String> {
        self.parse_or(key, default)
    }

    fn f32_or(&self, key: &str, default: f32) -> Result<f32, String> {
        self.parse_or(key, default)
    }

    fn bool_or(&self, key: &str, default: bool) -> Result<bool, String> {
        self.parse_or(key, default)
    }

    /// Optional typed flag: `None` when absent, error when unparseable.
    fn opt<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>, String> {
        self.get(key)
            .map(|v| {
                v.parse()
                    .map_err(|_| format!("flag --{key}: cannot parse value `{v}`"))
            })
            .transpose()
    }

    /// Optional `--threads` pin for the worker pool. Validated like every
    /// other flag (0 or garbage is a hard error), then installed via
    /// [`crate::pool::set_num_threads`] — which wins over the
    /// `AIMET_THREADS` env var because it runs before the pool's first
    /// lazy read.
    fn apply_threads(&self) -> Result<(), String> {
        if let Some(t) = self.opt::<usize>("threads")? {
            if t == 0 {
                return Err("flag --threads: must be >= 1".to_string());
            }
            crate::pool::set_num_threads(t);
        }
        Ok(())
    }

    fn effort(&self) -> Result<Effort, String> {
        match self.get("effort") {
            None | Some("fast") => Ok(Effort::Fast),
            Some("full") => Ok(Effort::Full),
            Some(v) => Err(format!("flag --effort: expected fast|full, got `{v}`")),
        }
    }
}

const USAGE: &str = "aimet — neural network quantization toolkit (AIMET reproduction)

USAGE: aimet <command> [--flags]

COMMANDS
  models                         list available zoo models
  config                         print the default runtime-config JSON (fig 3.4)
  train    --model M [--steps N --lr F --effort fast|full]
  ptq      --model M [--adaround true --effort fast|full]
  qat      --model M [--steps N --effort fast|full]
  compress --model M [--target-ratio F --effort fast|full]
                                 greedy spatial-SVD/channel-prune search to a
                                 MAC budget, then compress -> BN fold -> CLE ->
                                 quantize
  quantize-amp --model M [--weight-budget F --low-bw B --adaround true
                --adaround-iters N --calib-batches K --eval-batches K
                --effort fast|full]
                                 greedy per-layer weight bit-width search
                                 (AMP): drop insensitive layers to B bits
                                 (default 4, nibble-packed in the engine)
                                 until packed weight bytes fit F x the
                                 all-8-bit baseline (default 0.6), AdaRound
                                 the dropped layers, report eval delta
  infer    --model M [--batch N --batches K --threads T --effort fast|full]
                     [--profile --trace OUT.json --ranges OUT.csv]
                                 train + PTQ-calibrate, lower to the integer-only
                                 engine, report eval/agreement/latency vs the
                                 quantsim and FP32 paths; --threads pins the
                                 worker pool (overrides AIMET_THREADS);
                                 --profile prints the per-node time/GOPS/clip
                                 table, --trace writes Chrome trace-event JSON
                                 (open at ui.perfetto.dev), --ranges dumps
                                 per-channel weight ranges as CSV
  serve-bench --model M [--clients N --requests R --max-batch B
               --max-wait-ms MS --threads T --effort fast|full]
              [--queue-cap N --deadline-ms MS]
              [--fault-seed S --fault-rate P]
              [--metrics OUT.prom --drift-report OUT.csv
               --drift-sample N --shift-inputs F]
                                 batched int8 serving: latency percentiles +
                                 throughput, coalesced vs batch-1;
                                 --queue-cap bounds the admission queue
                                 (default 1024), --deadline-ms expires
                                 requests the batcher can't reach in time,
                                 --fault-seed/--fault-rate inject seeded
                                 deterministic forward panics + dispatch
                                 delays at rate P (chaos drill; errors are
                                 tallied, the server must survive),
                                 --metrics writes registry snapshots
                                 (Prometheus text, or JSON for .json paths),
                                 --drift-report writes per-node calibration
                                 drift verdicts as CSV, --drift-sample sets
                                 the monitor's 1-in-N batch cadence (default
                                 16), --shift-inputs re-runs with inputs
                                 scaled by F to exercise the drift detector
  debug    [--model M --effort fast|full]
                                 fig 4.5 debugging flow end-to-end on one model
  export   --model M --out DIR
  experiment <table4.1|table4.2|table5.1|table5.2|fig4.2|debug|all>
  runtime  [--dir D --run NAME]  list / smoke-run the PJRT artifacts
";

/// Accepted `--flags` (and positional budget) per subcommand — the strict
/// parser rejects anything outside this table.
fn command_spec(cmd: &str) -> Option<(&'static [&'static str], usize)> {
    Some(match cmd {
        "models" | "config" | "help" | "--help" | "-h" => (&[], 0),
        "train" => (&["model", "steps", "lr", "effort"], 0),
        "ptq" => (&["model", "adaround", "adaround-iters", "effort"], 0),
        "qat" => (&["model", "steps", "lr", "effort"], 0),
        "compress" => (
            &[
                "model",
                "target-ratio",
                "effort",
                "calib-batches",
                "eval-batches",
            ],
            0,
        ),
        "quantize-amp" => (
            &[
                "model",
                "weight-budget",
                "low-bw",
                "adaround",
                "adaround-iters",
                "calib-batches",
                "eval-batches",
                "effort",
            ],
            0,
        ),
        "infer" => (
            &[
                "model", "batch", "batches", "threads", "effort", "profile", "trace", "ranges",
            ],
            0,
        ),
        "serve-bench" => (
            &[
                "model",
                "clients",
                "requests",
                "max-batch",
                "max-wait-ms",
                "queue-cap",
                "deadline-ms",
                "fault-seed",
                "fault-rate",
                "threads",
                "effort",
                "metrics",
                "drift-report",
                "drift-sample",
                "shift-inputs",
            ],
            0,
        ),
        "debug" => (&["model", "effort"], 0),
        "export" => (&["model", "out", "effort"], 0),
        "experiment" => (&["effort"], 1),
        "runtime" => (&["dir", "run"], 0),
        _ => return None,
    })
}

/// Entry point for `aimet` (called from `rust/src/main.rs`).
pub fn cli_main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = run(&argv);
    std::process::exit(code);
}

/// Testable command dispatcher; returns the process exit code.
pub fn run(argv: &[String]) -> i32 {
    let Some(cmd) = argv.first() else {
        print!("{USAGE}");
        return 2;
    };
    let Some((allowed, max_pos)) = command_spec(cmd) else {
        eprintln!("unknown command: {cmd}\n{USAGE}");
        return 2;
    };
    let args = match Args::parse(&argv[1..], allowed, max_pos) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{cmd}: {e}");
            return 2;
        }
    };
    let result: Result<i32, String> = match cmd.as_str() {
        "models" => {
            for m in zoo::MODEL_NAMES {
                let g = zoo::build(m, 1).unwrap();
                println!(
                    "{m:<11} input {:?}  params {}  metric {}",
                    zoo::input_shape(m).unwrap(),
                    g.param_count(),
                    metrics::metric_name(m)
                );
            }
            Ok(0)
        }
        "config" => {
            println!("{}", default_config_json());
            Ok(0)
        }
        "train" => cmd_train(&args),
        "ptq" => cmd_ptq(&args),
        "qat" => cmd_qat(&args),
        "compress" => cmd_compress(&args),
        "quantize-amp" => cmd_quantize_amp(&args),
        "infer" => cmd_infer(&args),
        "serve-bench" => cmd_serve_bench(&args),
        "debug" => cmd_debug(&args),
        "export" => cmd_export(&args),
        "experiment" => cmd_experiment(
            args.positionals.first().map(|s| s.as_str()).unwrap_or("all"),
            &args,
        ),
        "runtime" => cmd_runtime(&args),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(0)
        }
        _ => unreachable!("command_spec gated"),
    };
    match result {
        Ok(code) => code,
        Err(e) => {
            eprintln!("{cmd}: {e}");
            2
        }
    }
}

fn cmd_train(args: &Args) -> Result<i32, String> {
    let model = args.model()?;
    let effort = args.effort()?;
    let steps = args.opt("steps")?;
    if steps == Some(0) {
        return Err("flag --steps: must be >= 1".to_string());
    }
    let (g, data, log) =
        experiments::trained_model_with(&model, effort, 1234, steps, args.opt("lr")?);
    println!("{}", log.render());
    let metric = evaluate_graph(&g, &model, &data, 6, 16)?;
    println!(
        "trained {model}: final loss {:.4}, {} = {:.2}",
        log.final_loss(),
        metrics::metric_name(&model),
        metric
    );
    Ok(0)
}

fn cmd_ptq(args: &Args) -> Result<i32, String> {
    let model = args.model()?;
    let effort = args.effort()?;
    let mut opts = PtqOptions::default();
    if args.bool_or("adaround", false)? {
        opts.use_adaround = true;
        opts.adaround.iterations = args.usize_or("adaround-iters", 300)?;
    }
    let (g, data, _) = experiments::trained_model(&model, effort, 1234);
    let fp32 = evaluate_graph(&g, &model, &data, 6, 16)?;
    let calib = data.calibration(4, 16);
    let out = standard_ptq_pipeline(&g, &calib, &opts);
    for line in &out.log {
        println!("ptq: {line}");
    }
    let q = evaluate_sim(&out.sim, &model, &data, 6, 16)?;
    println!(
        "{model}: FP32 {fp32:.2} -> W8/A8 PTQ {q:.2} ({})",
        metrics::metric_name(&model)
    );
    Ok(0)
}

fn cmd_qat(args: &Args) -> Result<i32, String> {
    let model = args.model()?;
    let effort = args.effort()?;
    let steps = args.usize_or("steps", 120)?;
    let lr = args.f32_or("lr", 0.01)?;
    let (g, data, _) = experiments::trained_model(&model, effort, 1234);
    let fp32 = evaluate_graph(&g, &model, &data, 6, 16)?;
    let calib = data.calibration(4, 16);
    let out = standard_ptq_pipeline(&g, &calib, &PtqOptions::default());
    let ptq = evaluate_sim(&out.sim, &model, &data, 6, 16)?;
    let mut sim = out.sim;
    let cfg = TrainConfig {
        steps,
        lr,
        ..Default::default()
    };
    let log = fit_qat(&mut sim, &model, &data, &cfg);
    println!("{}", log.render());
    let qat = evaluate_sim(&sim, &model, &data, 6, 16)?;
    println!(
        "{model}: FP32 {fp32:.2} | PTQ {ptq:.2} | QAT {qat:.2} ({})",
        metrics::metric_name(&model)
    );
    Ok(0)
}

fn cmd_compress(args: &Args) -> Result<i32, String> {
    let model = args.model()?;
    let target = args.f32_or("target-ratio", 0.5)?;
    if !(target > 0.0 && target < 1.0) {
        return Err(format!("--target-ratio must be in (0, 1), got {target}"));
    }
    let effort = args.effort()?;
    let calib_batches = args.usize_or("calib-batches", 4)?;
    let eval_batches = args.usize_or("eval-batches", 3)?;
    let (g, data, _) = experiments::trained_model(&model, effort, 1234);
    let mut input_shape = vec![1usize];
    input_shape.extend(zoo::input_shape(&model).unwrap());
    let calib = data.calibration(calib_batches, 16);
    let fp32 = evaluate_graph(&g, &model, &data, 6, 16)?;

    // Greedy per-layer ratio search (candidates scored on the pool).
    let eval = |g2: &crate::graph::Graph| {
        // `model` was validated above, so this cannot fail on model name.
        evaluate_graph(g2, &model, &data, eval_batches, 16).expect("validated model")
    };
    let opts = SearchOptions {
        target_ratio: target,
        ..Default::default()
    };
    let outcome = greedy_plan(&g, &calib, &input_shape, &eval, &opts);
    println!(
        "sensitivity: {} layers x {:?} ratios (baseline {} = {:.2}, {} MACs)",
        outcome.sensitivity.len(),
        opts.candidate_ratios,
        metrics::metric_name(&model),
        outcome.base_score,
        outcome.base_macs
    );
    for s in &outcome.sensitivity {
        let pts: Vec<String> = s
            .points
            .iter()
            .map(|p| format!("{}@{:.3}:{:.2}", p.kind.label(), p.ratio, p.score))
            .collect();
        println!("  {:<14} {}", s.layer, pts.join("  "));
    }
    for c in &outcome.plan.choices {
        println!("plan: {} {} @ ratio {:.3}", c.kind.label(), c.layer, c.ratio);
    }

    // Apply + quantize (compress -> BN fold -> CLE -> quantize).
    let (res, ptq) = compress_then_ptq(
        &g,
        &outcome.plan,
        &calib,
        &input_shape,
        &PtqOptions::default(),
    );
    for line in &res.log {
        println!("compress: {line}");
    }
    for line in &ptq.log {
        println!("ptq: {line}");
    }
    let compressed = evaluate_graph(&res.graph, &model, &data, 6, 16)?;
    let quantized = evaluate_sim(&ptq.sim, &model, &data, 6, 16)?;
    println!(
        "{model}: FP32 {fp32:.2} | compressed {compressed:.2} ({:.1}% MACs) | compressed+PTQ {quantized:.2} ({})",
        100.0 * res.mac_ratio(),
        metrics::metric_name(&model)
    );
    Ok(0)
}

fn cmd_quantize_amp(args: &Args) -> Result<i32, String> {
    let model = args.model()?;
    let budget = args.f32_or("weight-budget", 0.6)?;
    if !(budget > 0.0 && budget < 1.0) {
        return Err(format!("--weight-budget must be in (0, 1), got {budget}"));
    }
    let low_bw = args.usize_or("low-bw", 4)? as u32;
    if !(2..=4).contains(&low_bw) {
        // > 4-bit weights don't nibble-pack, so dropping to them saves no
        // packed bytes — the budget could never be met.
        return Err(format!("--low-bw must be in [2, 4], got {low_bw}"));
    }
    let use_adaround = args.bool_or("adaround", true)?;
    let effort = args.effort()?;
    let calib_batches = args.usize_or("calib-batches", 4)?;
    let eval_batches = args.usize_or("eval-batches", 3)?;
    let ptq = PtqOptions {
        adaround: crate::ptq::AdaroundParameters {
            iterations: args.usize_or("adaround-iters", 200)?,
            ..Default::default()
        },
        ..Default::default()
    };
    let (g, data, _) = experiments::trained_model(&model, effort, 1234);
    let calib = data.calibration(calib_batches, 16);
    let fp32 = evaluate_graph(&g, &model, &data, 6, 16)?;
    // `model` was validated above, so this cannot fail on model name.
    let eval = |sim: &crate::quantsim::QuantizationSimModel| {
        evaluate_sim(sim, &model, &data, eval_batches, 16).expect("validated model")
    };
    let opts = AmpOptions {
        weight_budget: budget,
        low_bw,
        adaround_low_bw_layers: use_adaround,
    };
    let out = amp_greedy_plan(&g, &calib, &eval, &ptq, &opts)?;
    println!(
        "sensitivity: {} layers probed at {low_bw}b (baseline {} = {:.2}, {} B packed)",
        out.sensitivity.len(),
        metrics::metric_name(&model),
        out.base_score,
        out.base_bytes
    );
    for c in &out.sensitivity {
        println!(
            "  {:<14} {low_bw}b score {:.2}  ({} B at 8b)",
            c.layer, c.score, c.bytes_base
        );
    }
    for (layer, bw) in &out.bws {
        println!("plan: {layer} -> {bw}b");
    }
    let qm = lower(&out.sim).map_err(|e| format!("lowering failed: {e}"))?;
    println!("{}", qm.describe());
    println!(
        "{model}: FP32 {fp32:.2} | W8A8 {:.2} | mixed W{low_bw}/W8 {:.2} (delta {:+.2}) | \
         packed weights {} -> {} B ({:.1}%)",
        out.base_score,
        out.final_score,
        out.eval_delta,
        out.base_bytes,
        out.achieved_bytes,
        100.0 * out.achieved_bytes as f64 / out.base_bytes.max(1) as f64
    );
    Ok(0)
}

/// Train (fast) + PTQ-calibrate + lower one zoo model onto the integer
/// engine, prepare serving samples. Shared by `infer` and `serve-bench`.
fn lowered_model(
    args: &Args,
) -> Result<(String, crate::engine::QuantizedModel, crate::quantsim::QuantizationSimModel, crate::graph::Graph, crate::task::TaskData), String> {
    let model = args.model()?;
    let effort = args.effort()?;
    let (g, data, _) = experiments::trained_model(&model, effort, 1234);
    let calib = data.calibration(4, 16);
    let out = standard_ptq_pipeline(&g, &calib, &PtqOptions::default());
    let qm = lower(&out.sim).map_err(|e| format!("lowering failed: {e}"))?;
    Ok((model, qm, out.sim, g, data))
}

fn cmd_infer(args: &Args) -> Result<i32, String> {
    let batch = args.usize_or("batch", 8)?;
    let batches = args.usize_or("batches", 4)?;
    if batch == 0 || batches == 0 {
        return Err("flags --batch/--batches must be >= 1".to_string());
    }
    let profile = args.bool_or("profile", false)?;
    let trace_path = args.get("trace").map(str::to_string);
    let ranges_path = args.get("ranges").map(str::to_string);
    if trace_path.as_deref() == Some("") || ranges_path.as_deref() == Some("") {
        return Err("flags --trace/--ranges need a non-empty output path".to_string());
    }
    args.apply_threads()?;
    let (model, qm, sim, g, data) = lowered_model(args)?;
    println!("{}", qm.describe());
    // The static arena plan the packed engine executes against, the
    // wavefront schedule it dispatches, and the SIMD tier of its kernels.
    let (x0, _) = data.batch(50_000, batch);
    let (fronts, width) = qm.wavefront_summary();
    println!(
        "{} | {fronts} wavefronts (max width {width}), {} fused epilogues | simd tier {} | threads {}",
        qm.memory_plan(x0.shape()).describe(),
        qm.fused_epilogues(),
        crate::quant::simd::active_tier(),
        crate::pool::num_threads()
    );
    // Per-node weight widths: mixed-precision (quantize-amp) models show
    // which layers run nibble-packed W4 panels and what they weigh.
    for (name, bw, bytes) in qm.weight_layers() {
        println!("  weight {name:<14} {bw:>2}b  {bytes:>8} B packed");
    }

    let out_enc = *qm.output_encoding();
    let mut scratch = crate::engine::Scratch::new();
    // Warm the scratch (plan + arena) so the timed loop below measures the
    // steady-state zero-allocation path, not one-time planning.
    std::hint::black_box(qm.forward_with(&x0, &mut scratch).data());
    let (mut m_fp32, mut m_sim, mut m_eng) = (0.0f32, 0.0f32, 0.0f32);
    let (mut t_fp32, mut t_sim, mut t_eng) = (0.0f64, 0.0f64, 0.0f64);
    let (mut worst_step, mut gt1, mut elems) = (0i32, 0usize, 0usize);
    for i in 0..batches {
        let (x, t) = data.batch(50_000 + i as u64, batch);
        let t0 = std::time::Instant::now();
        let y_fp = g.forward(&x);
        t_fp32 += t0.elapsed().as_secs_f64();
        let t0 = std::time::Instant::now();
        let y_sim = sim.forward(&x);
        t_sim += t0.elapsed().as_secs_f64();
        let t0 = std::time::Instant::now();
        let y_int = qm.forward_with(&x, &mut scratch);
        t_eng += t0.elapsed().as_secs_f64();
        // Agreement: both outputs as integers on the output grid.
        for (&q, &v) in y_int.data().iter().zip(y_sim.data()) {
            let d = (q as i32 - out_enc.quantize(v)).abs();
            worst_step = worst_step.max(d);
            gt1 += usize::from(d > 1);
            elems += 1;
        }
        let y_eng = y_int.dequantize();
        m_fp32 += crate::task::quality(&model, &y_fp, &t)?;
        m_sim += crate::task::quality(&model, &y_sim, &t)?;
        m_eng += crate::task::quality(&model, &y_eng, &t)?;
    }
    let n = batches as f32;
    let ms = |s: f64| s / batches as f64 * 1e3;
    println!(
        "{model} (batch {batch}, {batches} batches, {}):",
        metrics::metric_name(&model)
    );
    println!("  fp32     : {:7.2}  {:8.2} ms/batch", m_fp32 / n, ms(t_fp32));
    println!("  quantsim : {:7.2}  {:8.2} ms/batch", m_sim / n, ms(t_sim));
    println!("  engine   : {:7.2}  {:8.2} ms/batch (integer-only: {})",
        m_eng / n,
        ms(t_eng),
        qm.is_integer_only()
    );
    println!(
        "  engine vs sim: max deviation {worst_step} step(s), {gt1}/{elems} elements beyond 1 step"
    );

    if let Some(path) = &ranges_path {
        // Per-channel weight ranges of every weighted layer (the fig 4.2
        // diagnosis input), one CSV row per channel.
        let all = crate::visualize::weight_ranges(&g);
        let mut csv = String::from("layer,channel,min,max\n");
        for cr in &all {
            for (ch, (lo, hi)) in cr.ranges.iter().enumerate() {
                csv.push_str(&format!("{},{ch},{lo},{hi}\n", cr.layer));
            }
        }
        std::fs::write(path, csv).map_err(|e| format!("--ranges {path}: {e}"))?;
        println!(
            "  wrote per-channel weight ranges ({} layers) to {path}",
            all.len()
        );
    }

    if profile || trace_path.is_some() {
        // Re-run the same batches inside a profiling window: spans cost
        // ≤ 3% (bench-gated), so the timed loop above stays clean.
        let session = qm.profile_session();
        for i in 0..batches {
            let (x, _) = data.batch(50_000 + i as u64, batch);
            std::hint::black_box(qm.forward_with(&x, &mut scratch).data());
        }
        let prof = session.finish();
        if prof.dropped > 0 {
            eprintln!(
                "warning: profiler dropped {} span(s) (per-thread buffer overflow) — \
                 the table and trace below undercount; profile fewer batches per window",
                prof.dropped
            );
        }
        let meta = qm.profile_meta(x0.shape());
        let report = crate::obs::ProfileReport::build(&meta, &prof);
        print!("{}", report.render());
        if let Some(path) = &trace_path {
            let trace = crate::obs::chrome_trace(&meta, &prof);
            std::fs::write(path, trace.pretty()).map_err(|e| format!("--trace {path}: {e}"))?;
            println!("  wrote Chrome trace to {path} — open at ui.perfetto.dev");
        }
    }
    Ok(0)
}

fn cmd_serve_bench(args: &Args) -> Result<i32, String> {
    let clients = args.usize_or("clients", 4)?;
    let requests = args.usize_or("requests", 32)?;
    let max_batch = args.usize_or("max-batch", 8)?;
    let max_wait_ms = args.f32_or("max-wait-ms", 2.0)?;
    if clients == 0 || requests == 0 || max_batch == 0 || max_wait_ms < 0.0 {
        return Err(
            "flags --clients/--requests/--max-batch must be >= 1 and --max-wait-ms >= 0"
                .to_string(),
        );
    }
    let queue_cap = args.usize_or("queue-cap", crate::engine::DEFAULT_QUEUE_CAP)?;
    if queue_cap == 0 {
        return Err("flag --queue-cap must be >= 1".to_string());
    }
    let deadline = match args.opt::<f64>("deadline-ms")? {
        None => None,
        Some(ms) if ms.is_finite() && ms > 0.0 => {
            Some(std::time::Duration::from_secs_f64(ms / 1e3))
        }
        Some(ms) => {
            return Err(format!(
                "flag --deadline-ms: must be finite and > 0, got `{ms}`"
            ))
        }
    };
    let fault_rate = args.opt::<f64>("fault-rate")?;
    let fault_seed = args.opt::<u64>("fault-seed")?;
    let fault = match (fault_seed, fault_rate) {
        (_, Some(r)) if !r.is_finite() || !(0.0..=1.0).contains(&r) => {
            return Err(format!("flag --fault-rate: must be in [0, 1], got `{r}`"))
        }
        (None, None) => None,
        // A bare --fault-seed drills at a default 1% rate; a bare
        // --fault-rate uses the plan's default seed.
        (seed, rate) => {
            let mut plan = FaultPlan {
                seed: seed.unwrap_or(FaultPlan::default().seed),
                ..FaultPlan::default()
            };
            let r = rate.unwrap_or(0.01);
            plan.panic_rate = r;
            plan.delay_rate = r;
            Some(plan)
        }
    };
    let metrics_path = args.get("metrics").map(str::to_string);
    let drift_path = args.get("drift-report").map(str::to_string);
    if metrics_path.as_deref() == Some("") || drift_path.as_deref() == Some("") {
        return Err("flags --metrics/--drift-report need a non-empty output path".to_string());
    }
    let drift_sample = args.usize_or("drift-sample", 16)?;
    if drift_sample == 0 {
        return Err("flag --drift-sample must be >= 1".to_string());
    }
    let shift = match args.get("shift-inputs") {
        None => None,
        Some(v) => {
            let f: f32 = v
                .parse()
                .map_err(|_| format!("flag --shift-inputs: expected a number, got `{v}`"))?;
            if !f.is_finite() || f <= 0.0 {
                return Err(format!(
                    "flag --shift-inputs: factor must be finite and > 0, got `{v}`"
                ));
            }
            Some(f)
        }
    };
    args.apply_threads()?;
    let (model, qm, _, _, data) = lowered_model(args)?;
    println!("{}", qm.describe());
    let qm = std::sync::Arc::new(qm);
    let samples: Vec<crate::tensor::Tensor> =
        (0..32).map(|i| data.batch(90_000 + i, 1).0).collect();
    let wait = std::time::Duration::from_secs_f32(max_wait_ms / 1e3);
    // Snapshot the registry to the metrics sink for the whole run (plus a
    // final write at stop, so short runs still leave a complete file).
    let monitor = metrics_path
        .as_ref()
        .map(|p| ServeMonitor::start(p.clone(), std::time::Duration::from_millis(500)));
    let drift_cfg = DriftConfig {
        sample_every: drift_sample as u64,
        ..DriftConfig::default()
    };

    // Batch-1 baseline: same traffic, no coalescing.
    let b1 = run_serve_bench(
        std::sync::Arc::clone(&qm),
        &samples,
        clients,
        requests,
        BatchConfig {
            max_batch: 1,
            max_wait: wait,
        },
    );
    // Batched run, drift-monitored on calibration-distribution traffic:
    // the baseline the shifted phase is judged against.
    let mon = std::sync::Arc::new(qm.drift_monitor(drift_cfg));
    let bn = run_serve_bench_with(
        std::sync::Arc::clone(&qm),
        &samples,
        clients,
        requests,
        ServeOptions {
            cfg: BatchConfig {
                max_batch,
                max_wait: wait,
            },
            label: Some(model.clone()),
            drift: Some(std::sync::Arc::clone(&mon)),
            queue_cap,
            deadline,
            fault,
        },
    );
    println!("{model} serving ({clients} clients x {requests} reqs, max wait {max_wait_ms} ms):");
    println!("  batch-1    : {}", b1.render());
    println!("  max-batch {max_batch}: {}", bn.render());
    if let Some(fp) = &fault {
        println!(
            "  fault drill (seed {}, panic/delay rate {:.3}): {} panics + {} delays injected, \
             {} requests answered ModelPanicked, {} expired, server drained clean",
            fp.seed,
            fp.panic_rate,
            bn.stats.injected_panics,
            bn.stats.injected_delays,
            bn.stats.panicked,
            bn.stats.expired
        );
    }
    println!(
        "  batched speedup: {:.2}x throughput, mean batch {:.2}",
        bn.throughput_sps / b1.throughput_sps.max(1e-9),
        bn.stats.mean_batch()
    );
    let base_report = mon.report();
    print!("  {}", base_report.render());

    // Optional detector exercise: replay the same traffic with inputs
    // scaled/offset away from the calibration distribution through a
    // fresh monitor — the grids stop fitting and the report should flag.
    let shifted_report = match shift {
        None => None,
        Some(f) => {
            let shifted: Vec<crate::tensor::Tensor> = samples
                .iter()
                .map(|t| {
                    let data: Vec<f32> =
                        t.data().iter().map(|&v| f * v + 0.1 * (f - 1.0)).collect();
                    crate::tensor::Tensor::new(t.shape(), data)
                })
                .collect();
            let mon2 = std::sync::Arc::new(qm.drift_monitor(drift_cfg));
            let bs = run_serve_bench_with(
                std::sync::Arc::clone(&qm),
                &shifted,
                clients,
                requests,
                ServeOptions {
                    cfg: BatchConfig {
                        max_batch,
                        max_wait: wait,
                    },
                    label: Some(format!("{model}_shifted")),
                    drift: Some(std::sync::Arc::clone(&mon2)),
                    queue_cap,
                    deadline,
                    // The shifted replay grades drift, not robustness —
                    // keep it unfaulted so verdicts compare cleanly.
                    fault: None,
                },
            );
            println!("  shifted x{f}: {}", bs.render());
            let r = mon2.report();
            print!("  {}", r.render());
            Some(r)
        }
    };

    if let Some(path) = &drift_path {
        let mut csv = String::from(DriftReport::csv_header());
        csv.push_str(&base_report.to_csv_rows("baseline"));
        if let Some(r) = &shifted_report {
            csv.push_str(&r.to_csv_rows("shifted"));
        }
        std::fs::write(path, csv).map_err(|e| format!("--drift-report {path}: {e}"))?;
        println!("  wrote drift report to {path}");
    }
    if let Some(m) = monitor {
        m.stop();
        if let Some(p) = &metrics_path {
            println!("  wrote metrics snapshot to {p}");
        }
    }
    Ok(0)
}

fn cmd_debug(args: &Args) -> Result<i32, String> {
    let model = args.model()?;
    let report = experiments::debug_flow_for(&model, args.effort()?);
    print!("{}", report.render());
    Ok(0)
}

fn cmd_export(args: &Args) -> Result<i32, String> {
    let model = args.model()?;
    let out_dir = std::path::PathBuf::from(args.get("out").unwrap_or("./exported"));
    let (g, data, _) = experiments::trained_model(&model, args.effort()?, 1234);
    let calib = data.calibration(4, 16);
    let out = standard_ptq_pipeline(&g, &calib, &PtqOptions::default());
    match out.sim.export(&out_dir, &model) {
        Ok(()) => {
            println!(
                "exported {model} model + encodings to {} ({}.json/.bin, {}_encodings.json)",
                out_dir.display(),
                model,
                model
            );
            Ok(0)
        }
        Err(e) => {
            eprintln!("export failed: {e:#}");
            Ok(1)
        }
    }
}

fn cmd_experiment(id: &str, args: &Args) -> Result<i32, String> {
    let effort = args.effort()?;
    const IDS: [&str; 8] = [
        "table4.1", "table4.2", "table5.1", "table5.2", "fig4.2", "fig4.3", "debug", "fig4.5",
    ];
    if id != "all" && !IDS.contains(&id) {
        return Err(format!(
            "unknown experiment `{id}`; valid: {} all",
            IDS.join(" ")
        ));
    }
    let run_one = |id: &str| match id {
        "table4.1" => print!("{}", experiments::render_table_4_1(&experiments::table_4_1(effort))),
        "table4.2" => print!("{}", experiments::render_table_4_2(&experiments::table_4_2(effort))),
        "table5.1" => print!("{}", experiments::render_table_5_1(&experiments::table_5_1(effort))),
        "table5.2" => print!("{}", experiments::render_table_5_2(&experiments::table_5_2(effort))),
        "fig4.2" | "fig4.3" => {
            print!("{}", experiments::render_fig_4_2_4_3(&experiments::fig_4_2_4_3(effort)))
        }
        "debug" | "fig4.5" => print!("{}", experiments::debug_flow_demo(effort).render()),
        other => unreachable!("validated above: {other}"),
    };
    if id == "all" {
        for id in ["table4.1", "table4.2", "table5.1", "table5.2", "fig4.2", "debug"] {
            println!("=== {id} ===");
            run_one(id);
            println!();
        }
    } else {
        run_one(id);
    }
    Ok(0)
}

fn cmd_runtime(args: &Args) -> Result<i32, String> {
    let dir = args
        .get("dir")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(Runtime::artifacts_dir);
    if !Runtime::available(&dir) {
        eprintln!(
            "no artifacts at {} — run `make artifacts` first",
            dir.display()
        );
        return Ok(1);
    }
    let mut rt = match Runtime::open(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("runtime open failed: {e:#}");
            return Ok(1);
        }
    };
    if let Some(name) = args.get("run").map(str::to_string) {
        // Smoke-run a forward program with zoo weights + a synthetic batch.
        let Some(model) = name.strip_suffix("_fwd").map(str::to_string) else {
            eprintln!("--run expects a *_fwd program");
            return Ok(2);
        };
        let Some(g) = zoo::build(&model, 1234) else {
            return Err(format!(
                "unknown model `{model}` in --run {name}; valid models: {}",
                zoo::MODEL_NAMES.join(" ")
            ));
        };
        let data = TaskData::new(&model, 7)?;
        let Some(spec) = rt.spec(&name).cloned() else {
            return Err(format!("program `{name}` not in the artifacts manifest"));
        };
        let batch = spec.inputs.last().unwrap()[0];
        let (x, _) = data.batch(0, batch);
        let mut inputs = graph_param_tensors(&g);
        inputs.push(x);
        match rt.execute(&name, &inputs) {
            Ok(outs) => {
                println!(
                    "{name}: ok, output shapes {:?}",
                    outs.iter().map(|t| t.shape().to_vec()).collect::<Vec<_>>()
                );
                Ok(0)
            }
            Err(e) => {
                eprintln!("{name} failed: {e:#}");
                Ok(1)
            }
        }
    } else {
        for p in rt.programs() {
            println!(
                "{:<24} {:<28} {} inputs, {} outputs — {}",
                p.name,
                p.file,
                p.inputs.len(),
                p.outputs.len(),
                p.desc
            );
        }
        Ok(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn no_args_prints_usage() {
        assert_eq!(run(&[]), 2);
    }

    #[test]
    fn unknown_command_errors() {
        assert_eq!(run(&sv(&["frobnicate"])), 2);
    }

    #[test]
    fn models_and_config_succeed() {
        assert_eq!(run(&sv(&["models"])), 0);
        assert_eq!(run(&sv(&["config"])), 0);
        assert_eq!(run(&sv(&["help"])), 0);
    }

    #[test]
    fn flag_parser_handles_pairs() {
        let a = Args::parse(
            &sv(&["--model", "resmini", "--steps", "42"]),
            &["model", "steps", "lr"],
            0,
        )
        .unwrap();
        assert_eq!(a.model().unwrap(), "resmini");
        assert_eq!(a.usize_or("steps", 0).unwrap(), 42);
        assert_eq!(a.usize_or("missing", 7).unwrap(), 7);
        assert_eq!(a.f32_or("lr", 0.5).unwrap(), 0.5);
        assert_eq!(a.opt::<usize>("steps").unwrap(), Some(42));
        assert_eq!(a.opt::<f32>("lr").unwrap(), None);
    }

    #[test]
    fn malformed_flag_values_are_errors_not_defaults() {
        let a = Args::parse(
            &sv(&["--steps", "4x2", "--lr", "0,5", "--effort", "ful"]),
            &["steps", "lr", "effort"],
            0,
        )
        .unwrap();
        assert!(a.usize_or("steps", 0).is_err());
        assert!(a.f32_or("lr", 0.5).is_err());
        assert!(a.effort().is_err());
        // Through the dispatcher: exit 2, never a silent default-config run.
        assert_eq!(run(&sv(&["compress", "--target-ratio", "0,5"])), 2);
        assert_eq!(run(&sv(&["qat", "--steps", "many"])), 2);
        assert_eq!(run(&sv(&["debug", "--effort", "ful"])), 2);
        // Model-name typos error cleanly instead of panicking in zoo::build.
        assert_eq!(run(&sv(&["ptq", "--model", "mobimimi"])), 2);
        assert_eq!(run(&sv(&["train", "--model", "resmini", "--steps", "0"])), 2);
        // Experiment-id typos exit 2 instead of printing-and-succeeding.
        assert_eq!(run(&sv(&["experiment", "tabel4.1"])), 2);
    }

    #[test]
    fn unknown_flag_is_an_error_listing_valid_flags() {
        let err = Args::parse(&sv(&["--tagret-ratio", "0.5"]), &["target-ratio"], 0)
            .unwrap_err();
        assert!(err.contains("unknown flag --tagret-ratio"), "{err}");
        assert!(err.contains("--target-ratio"), "{err}");
        // And through the dispatcher: exit code 2, not a silent default run.
        assert_eq!(run(&sv(&["compress", "--tagret-ratio", "0.5"])), 2);
        assert_eq!(run(&sv(&["train", "--model", "resmini", "--bogus", "1"])), 2);
    }

    #[test]
    fn stray_positionals_are_rejected() {
        let err = Args::parse(&sv(&["resmini"]), &["model"], 0).unwrap_err();
        assert!(err.contains("unexpected argument `resmini`"), "{err}");
        assert_eq!(run(&sv(&["ptq", "resmini"])), 2);
        // `experiment` accepts exactly one positional.
        assert!(Args::parse(&sv(&["table4.1"]), &["effort"], 1).is_ok());
        let err = Args::parse(&sv(&["table4.1", "extra"]), &["effort"], 1).unwrap_err();
        assert!(err.contains("unexpected argument `extra`"), "{err}");
    }

    #[test]
    fn flag_missing_value_is_an_error() {
        let err = Args::parse(&sv(&["--model"]), &["model"], 0).unwrap_err();
        assert!(err.contains("requires a value"), "{err}");
        let err =
            Args::parse(&sv(&["--model", "--steps", "3"]), &["model", "steps"], 0).unwrap_err();
        assert!(err.contains("--model requires a value"), "{err}");
    }

    #[test]
    fn compress_rejects_out_of_range_target() {
        assert_eq!(run(&sv(&["compress", "--target-ratio", "1.5"])), 2);
    }

    /// `quantize-amp` validates its flags before any training or search
    /// work starts (all exit 2, no panic).
    #[test]
    fn quantize_amp_validates_cheaply() {
        assert_eq!(run(&sv(&["quantize-amp", "--weight-budget", "1.5"])), 2);
        assert_eq!(run(&sv(&["quantize-amp", "--weight-budget", "0"])), 2);
        assert_eq!(run(&sv(&["quantize-amp", "--weight-budget", "half"])), 2);
        // Only widths that nibble-pack (<= 4) can save packed bytes.
        assert_eq!(run(&sv(&["quantize-amp", "--low-bw", "8"])), 2);
        assert_eq!(run(&sv(&["quantize-amp", "--low-bw", "1"])), 2);
        assert_eq!(run(&sv(&["quantize-amp", "--low-bw", "four"])), 2);
        assert_eq!(run(&sv(&["quantize-amp", "--model", "mobimimi"])), 2);
        assert_eq!(run(&sv(&["quantize-amp", "--adaround", "maybe"])), 2);
        assert_eq!(run(&sv(&["quantize-amp", "--bogus", "1"])), 2);
        // And the AMP flags belong to quantize-amp alone.
        assert_eq!(run(&sv(&["infer", "--weight-budget", "0.5"])), 2);
        assert_eq!(run(&sv(&["compress", "--low-bw", "4"])), 2);
    }

    /// The engine commands validate flags and model names before any
    /// training/lowering work starts (all exit 2, no panic).
    #[test]
    fn infer_and_serve_bench_validate_cheaply() {
        assert_eq!(run(&sv(&["infer", "--batch", "0"])), 2);
        assert_eq!(run(&sv(&["infer", "--batches", "0"])), 2);
        assert_eq!(run(&sv(&["infer", "--model", "mobimimi"])), 2);
        assert_eq!(run(&sv(&["infer", "--bogus", "1"])), 2);
        assert_eq!(run(&sv(&["infer", "--threads", "0"])), 2);
        assert_eq!(run(&sv(&["infer", "--threads", "two"])), 2);
        assert_eq!(run(&sv(&["serve-bench", "--clients", "zero"])), 2);
        assert_eq!(run(&sv(&["serve-bench", "--max-batch", "0"])), 2);
        assert_eq!(run(&sv(&["serve-bench", "--max-wait-ms", "-1"])), 2);
        assert_eq!(run(&sv(&["serve-bench", "--model", "resmimi"])), 2);
        assert_eq!(run(&sv(&["serve-bench", "--threads", "0"])), 2);
    }

    /// The serving observability flags validate before any training or
    /// lowering work starts (all exit 2, no panic, nothing written).
    #[test]
    fn serve_bench_observability_flags_validate_cheaply() {
        // Output-path flags need their value, and a non-empty one.
        assert_eq!(run(&sv(&["serve-bench", "--metrics"])), 2);
        assert_eq!(run(&sv(&["serve-bench", "--drift-report"])), 2);
        assert_eq!(run(&sv(&["serve-bench", "--metrics", ""])), 2);
        assert_eq!(run(&sv(&["serve-bench", "--drift-report", ""])), 2);
        // Sampling cadence is 1-in-N, so N must be >= 1 and numeric.
        assert_eq!(run(&sv(&["serve-bench", "--drift-sample", "0"])), 2);
        assert_eq!(run(&sv(&["serve-bench", "--drift-sample", "often"])), 2);
        // The shift factor must be a finite number > 0.
        assert_eq!(run(&sv(&["serve-bench", "--shift-inputs", "0"])), 2);
        assert_eq!(run(&sv(&["serve-bench", "--shift-inputs", "-2"])), 2);
        assert_eq!(run(&sv(&["serve-bench", "--shift-inputs", "abc"])), 2);
        assert_eq!(run(&sv(&["serve-bench", "--shift-inputs", "inf"])), 2);
        // And these are serve-bench flags only.
        assert_eq!(run(&sv(&["infer", "--shift-inputs", "2"])), 2);
        assert_eq!(run(&sv(&["infer", "--drift-report", "d.csv"])), 2);
    }

    /// The robustness flags (admission control, deadlines, fault
    /// injection) validate before any training or lowering work starts.
    #[test]
    fn serve_bench_robustness_flags_validate_cheaply() {
        // Admission control: the queue bound is >= 1 and numeric.
        assert_eq!(run(&sv(&["serve-bench", "--queue-cap", "0"])), 2);
        assert_eq!(run(&sv(&["serve-bench", "--queue-cap", "deep"])), 2);
        // Deadlines are finite positive milliseconds.
        assert_eq!(run(&sv(&["serve-bench", "--deadline-ms", "0"])), 2);
        assert_eq!(run(&sv(&["serve-bench", "--deadline-ms", "-5"])), 2);
        assert_eq!(run(&sv(&["serve-bench", "--deadline-ms", "inf"])), 2);
        assert_eq!(run(&sv(&["serve-bench", "--deadline-ms", "soon"])), 2);
        // Fault rates are probabilities; seeds are u64.
        assert_eq!(run(&sv(&["serve-bench", "--fault-rate", "1.5"])), 2);
        assert_eq!(run(&sv(&["serve-bench", "--fault-rate", "-0.1"])), 2);
        assert_eq!(run(&sv(&["serve-bench", "--fault-rate", "nan"])), 2);
        assert_eq!(run(&sv(&["serve-bench", "--fault-seed", "-1"])), 2);
        assert_eq!(run(&sv(&["serve-bench", "--fault-seed", "lucky"])), 2);
        // And they belong to serve-bench alone.
        assert_eq!(run(&sv(&["infer", "--queue-cap", "8"])), 2);
        assert_eq!(run(&sv(&["infer", "--fault-rate", "0.1"])), 2);
    }

    #[test]
    fn switch_flags_take_no_value() {
        // `--profile` is a switch: it consumes nothing, so a value-flag
        // may follow immediately.
        let a = Args::parse(
            &sv(&["--profile", "--batch", "2"]),
            &["profile", "batch"],
            0,
        )
        .unwrap();
        assert!(a.bool_or("profile", false).unwrap());
        assert_eq!(a.usize_or("batch", 0).unwrap(), 2);
        // Absent switch = default false.
        let a = Args::parse(&sv(&["--batch", "2"]), &["profile", "batch"], 0).unwrap();
        assert!(!a.bool_or("profile", false).unwrap());
    }

    /// The observability/diagnostics flags validate before any work starts.
    #[test]
    fn profile_trace_ranges_and_debug_model_validate_cheaply() {
        // Value flags still need their value...
        assert_eq!(run(&sv(&["infer", "--trace"])), 2);
        assert_eq!(run(&sv(&["infer", "--ranges"])), 2);
        // ...and --profile is only an infer flag.
        assert_eq!(run(&sv(&["serve-bench", "--profile"])), 2);
        assert_eq!(run(&sv(&["ptq", "--profile"])), 2);
        // `debug` validates its model name and rejects strangers.
        assert_eq!(run(&sv(&["debug", "--model", "mobimimi"])), 2);
        assert_eq!(run(&sv(&["debug", "--bogus", "1"])), 2);
    }
}
