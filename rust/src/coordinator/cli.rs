//! The `aimet` command-line interface.
//!
//! Hand-rolled argument parsing (the offline build carries no clap); every
//! command maps to one paper workflow:
//!
//! ```text
//! aimet models                         list zoo models
//! aimet config                         print the default runtime config JSON
//! aimet train      --model M [...]     FP32 training (loss curve)
//! aimet ptq        --model M [...]     fig 4.1 pipeline + eval report
//! aimet qat        --model M [...]     fig 5.2 pipeline + eval report
//! aimet debug      --model M           fig 4.5 debugging flow
//! aimet export     --model M --out D   train + ptq + export encodings (§3.3)
//! aimet experiment <id>                table4.1|table4.2|table5.1|table5.2|fig4.2|all
//! aimet runtime    [--run NAME]        list / smoke-run PJRT artifacts
//! ```

use super::experiments::{self, Effort};
use crate::ptq::{standard_ptq_pipeline, PtqOptions};
use crate::qat::{fit_qat, TrainConfig};
use crate::quantsim::default_config_json;
use crate::runtime::{graph_param_tensors, Runtime};
use crate::task::{evaluate_graph, evaluate_sim, TaskData};
use crate::{metrics, zoo};

/// Minimal flag parser: `--key value` pairs after the subcommand.
struct Args {
    flags: std::collections::BTreeMap<String, String>,
}

impl Args {
    fn parse(rest: &[String]) -> Args {
        let mut flags = std::collections::BTreeMap::new();
        let mut i = 0;
        while i < rest.len() {
            if let Some(key) = rest[i].strip_prefix("--") {
                let val = rest.get(i + 1).cloned().unwrap_or_default();
                flags.insert(key.to_string(), val);
                i += 2;
            } else {
                i += 1;
            }
        }
        Args { flags }
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn model(&self) -> String {
        self.get("model").unwrap_or("mobimini").to_string()
    }

    fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    fn f32_or(&self, key: &str, default: f32) -> f32 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    fn effort(&self) -> Effort {
        match self.get("effort") {
            Some("full") => Effort::Full,
            _ => Effort::Fast,
        }
    }
}

const USAGE: &str = "aimet — neural network quantization toolkit (AIMET reproduction)

USAGE: aimet <command> [--flags]

COMMANDS
  models                         list available zoo models
  config                         print the default runtime-config JSON (fig 3.4)
  train   --model M [--steps N --lr F --effort fast|full]
  ptq     --model M [--adaround true --effort fast|full]
  qat     --model M [--steps N --effort fast|full]
  debug   --model M [--effort fast|full]
  export  --model M --out DIR
  experiment <table4.1|table4.2|table5.1|table5.2|fig4.2|debug|all>
  runtime [--dir D --run NAME]   list / smoke-run the PJRT artifacts
";

/// Entry point for `aimet` (called from `rust/src/main.rs`).
pub fn cli_main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = run(&argv);
    std::process::exit(code);
}

/// Testable command dispatcher; returns the process exit code.
pub fn run(argv: &[String]) -> i32 {
    let Some(cmd) = argv.first() else {
        print!("{USAGE}");
        return 2;
    };
    let args = Args::parse(&argv[1..]);
    match cmd.as_str() {
        "models" => {
            for m in zoo::MODEL_NAMES {
                let g = zoo::build(m, 1).unwrap();
                println!(
                    "{m:<11} input {:?}  params {}  metric {}",
                    zoo::input_shape(m).unwrap(),
                    g.param_count(),
                    metrics::metric_name(m)
                );
            }
            0
        }
        "config" => {
            println!("{}", default_config_json());
            0
        }
        "train" => cmd_train(&args),
        "ptq" => cmd_ptq(&args),
        "qat" => cmd_qat(&args),
        "debug" => cmd_debug(&args),
        "export" => cmd_export(&args),
        "experiment" => cmd_experiment(argv.get(1).map(|s| s.as_str()).unwrap_or("all"), &args),
        "runtime" => cmd_runtime(&args),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            0
        }
        other => {
            eprintln!("unknown command: {other}\n{USAGE}");
            2
        }
    }
}

fn cmd_train(args: &Args) -> i32 {
    let model = args.model();
    let effort = args.effort();
    let (g, data, log) = experiments::trained_model(&model, effort, 1234);
    println!("{}", log.render());
    let metric = evaluate_graph(&g, &model, &data, 6, 16);
    println!(
        "trained {model}: final loss {:.4}, {} = {:.2}",
        log.final_loss(),
        metrics::metric_name(&model),
        metric
    );
    0
}

fn cmd_ptq(args: &Args) -> i32 {
    let model = args.model();
    let effort = args.effort();
    let (g, data, _) = experiments::trained_model(&model, effort, 1234);
    let fp32 = evaluate_graph(&g, &model, &data, 6, 16);
    let calib = data.calibration(4, 16);
    let mut opts = PtqOptions::default();
    if args.get("adaround") == Some("true") {
        opts.use_adaround = true;
        opts.adaround.iterations = args.usize_or("adaround-iters", 300);
    }
    let out = standard_ptq_pipeline(&g, &calib, &opts);
    for line in &out.log {
        println!("ptq: {line}");
    }
    let q = evaluate_sim(&out.sim, &model, &data, 6, 16);
    println!(
        "{model}: FP32 {fp32:.2} -> W8/A8 PTQ {q:.2} ({})",
        metrics::metric_name(&model)
    );
    0
}

fn cmd_qat(args: &Args) -> i32 {
    let model = args.model();
    let effort = args.effort();
    let (g, data, _) = experiments::trained_model(&model, effort, 1234);
    let fp32 = evaluate_graph(&g, &model, &data, 6, 16);
    let calib = data.calibration(4, 16);
    let out = standard_ptq_pipeline(&g, &calib, &PtqOptions::default());
    let ptq = evaluate_sim(&out.sim, &model, &data, 6, 16);
    let mut sim = out.sim;
    let cfg = TrainConfig {
        steps: args.usize_or("steps", 120),
        lr: args.f32_or("lr", 0.01),
        ..Default::default()
    };
    let log = fit_qat(&mut sim, &model, &data, &cfg);
    println!("{}", log.render());
    let qat = evaluate_sim(&sim, &model, &data, 6, 16);
    println!(
        "{model}: FP32 {fp32:.2} | PTQ {ptq:.2} | QAT {qat:.2} ({})",
        metrics::metric_name(&model)
    );
    0
}

fn cmd_debug(args: &Args) -> i32 {
    let _ = args;
    let report = experiments::debug_flow_demo(args.effort());
    print!("{}", report.render());
    0
}

fn cmd_export(args: &Args) -> i32 {
    let model = args.model();
    let out_dir = std::path::PathBuf::from(args.get("out").unwrap_or("./exported"));
    let (g, data, _) = experiments::trained_model(&model, args.effort(), 1234);
    let calib = data.calibration(4, 16);
    let out = standard_ptq_pipeline(&g, &calib, &PtqOptions::default());
    match out.sim.export(&out_dir, &model) {
        Ok(()) => {
            println!(
                "exported {model} model + encodings to {} ({}.json/.bin, {}_encodings.json)",
                out_dir.display(),
                model,
                model
            );
            0
        }
        Err(e) => {
            eprintln!("export failed: {e:#}");
            1
        }
    }
}

fn cmd_experiment(id: &str, args: &Args) -> i32 {
    let effort = args.effort();
    let run_one = |id: &str| match id {
        "table4.1" => print!("{}", experiments::render_table_4_1(&experiments::table_4_1(effort))),
        "table4.2" => print!("{}", experiments::render_table_4_2(&experiments::table_4_2(effort))),
        "table5.1" => print!("{}", experiments::render_table_5_1(&experiments::table_5_1(effort))),
        "table5.2" => print!("{}", experiments::render_table_5_2(&experiments::table_5_2(effort))),
        "fig4.2" | "fig4.3" => {
            print!("{}", experiments::render_fig_4_2_4_3(&experiments::fig_4_2_4_3(effort)))
        }
        "debug" | "fig4.5" => print!("{}", experiments::debug_flow_demo(effort).render()),
        other => eprintln!("unknown experiment {other}"),
    };
    if id == "all" {
        for id in ["table4.1", "table4.2", "table5.1", "table5.2", "fig4.2", "debug"] {
            println!("=== {id} ===");
            run_one(id);
            println!();
        }
    } else {
        run_one(id);
    }
    0
}

fn cmd_runtime(args: &Args) -> i32 {
    let dir = args
        .get("dir")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(Runtime::artifacts_dir);
    if !Runtime::available(&dir) {
        eprintln!(
            "no artifacts at {} — run `make artifacts` first",
            dir.display()
        );
        return 1;
    }
    let mut rt = match Runtime::open(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("runtime open failed: {e:#}");
            return 1;
        }
    };
    if let Some(name) = args.get("run").map(str::to_string) {
        // Smoke-run a forward program with zoo weights + a synthetic batch.
        let Some(model) = name.strip_suffix("_fwd").map(str::to_string) else {
            eprintln!("--run expects a *_fwd program");
            return 2;
        };
        let g = zoo::build(&model, 1234).unwrap();
        let data = TaskData::new(&model, 7);
        let spec = rt.spec(&name).expect("program in manifest").clone();
        let batch = spec.inputs.last().unwrap()[0];
        let (x, _) = data.batch(0, batch);
        let mut inputs = graph_param_tensors(&g);
        inputs.push(x);
        match rt.execute(&name, &inputs) {
            Ok(outs) => {
                println!(
                    "{name}: ok, output shapes {:?}",
                    outs.iter().map(|t| t.shape().to_vec()).collect::<Vec<_>>()
                );
                0
            }
            Err(e) => {
                eprintln!("{name} failed: {e:#}");
                1
            }
        }
    } else {
        for p in rt.programs() {
            println!(
                "{:<24} {:<28} {} inputs, {} outputs — {}",
                p.name,
                p.file,
                p.inputs.len(),
                p.outputs.len(),
                p.desc
            );
        }
        0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn no_args_prints_usage() {
        assert_eq!(run(&[]), 2);
    }

    #[test]
    fn unknown_command_errors() {
        assert_eq!(run(&sv(&["frobnicate"])), 2);
    }

    #[test]
    fn models_and_config_succeed() {
        assert_eq!(run(&sv(&["models"])), 0);
        assert_eq!(run(&sv(&["config"])), 0);
        assert_eq!(run(&sv(&["help"])), 0);
    }

    #[test]
    fn flag_parser_handles_pairs() {
        let a = Args::parse(&sv(&["--model", "resmini", "--steps", "42"]));
        assert_eq!(a.model(), "resmini");
        assert_eq!(a.usize_or("steps", 0), 42);
        assert_eq!(a.usize_or("missing", 7), 7);
        assert_eq!(a.f32_or("lr", 0.5), 0.5);
    }
}
