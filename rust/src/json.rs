//! Minimal JSON substrate: value type, recursive-descent parser, and a
//! pretty-printing writer.
//!
//! AIMET's runtime-configuration files (§3.4 of the paper) and the exported
//! quantization encodings (§3.3) are JSON; the offline vendor set has no
//! `serde`/`serde_json`, so this module implements the subset of JSON we
//! need (full spec minus `\u` surrogate pairs are still handled) from
//! scratch.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a [`BTreeMap`] so output ordering is
/// deterministic — important for golden-file tests of exported encodings.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Object field lookup; returns `None` for non-objects.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Insert into an object; panics if `self` is not an object.
    pub fn set(&mut self, key: &str, val: Json) {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val);
            }
            _ => panic!("Json::set on non-object"),
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u32(&self) -> Option<u32> {
        self.as_f64().map(|f| f as u32)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            // AIMET configs encode booleans as the strings "True"/"False".
            Json::Str(s) if s == "True" || s == "true" => Some(true),
            Json::Str(s) if s == "False" || s == "false" => Some(false),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize with 2-space indentation and trailing newline-free output.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0);
        out
    }

    /// Serialize compactly (no whitespace).
    pub fn compact(&self) -> String {
        let mut out = String::new();
        self.write_compact(&mut out);
        out
    }

    fn write(&self, out: &mut String, indent: usize) {
        let pad = "  ".repeat(indent);
        let pad1 = "  ".repeat(indent + 1);
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => out.push_str(&fmt_num(*n)),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    out.push_str(&pad1);
                    v.write(out, indent + 1);
                    if i + 1 < a.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                out.push_str(&pad);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    out.push_str(&pad1);
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, indent + 1);
                    if i + 1 < m.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                out.push_str(&pad);
                out.push('}');
            }
        }
    }

    fn write_compact(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => out.push_str(&fmt_num(*n)),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write_compact(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write_compact(out);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.pretty())
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<f32> for Json {
    fn from(v: f32) -> Self {
        Json::Num(v as f64)
    }
}
impl From<u32> for Json {
    fn from(v: u32) -> Self {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(|x| x.into()).collect())
    }
}

fn fmt_num(n: f64) -> String {
    if n.is_finite() && n == n.trunc() && n.abs() < 1e15 {
        format!("{}", n as i64)
    } else if n.is_finite() {
        // Ryu-style shortest output is overkill; 17 significant digits
        // round-trips every f64.
        let s = format!("{n:e}");
        if s.contains('e') {
            // "1.5e-7" style is valid JSON.
            s
        } else {
            s
        }
    } else {
        // JSON has no NaN/Inf; clamp to null-adjacent sentinel.
        "null".to_string()
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document. Returns a descriptive error with byte offset on
/// malformed input.
pub fn parse(src: &str) -> Result<Json, String> {
    let mut p = Parser {
        bytes: src.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing characters at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", b as char, self.pos.saturating_sub(1)))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(format!("unexpected '{}' at byte {}", c as char, self.pos)),
            None => Err("unexpected end of input".to_string()),
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(format!("invalid literal at byte {}", self.pos))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            arr.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(arr)),
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or("eof in \\u escape")? as char;
                            code = code * 16
                                + c.to_digit(16).ok_or(format!("bad hex digit '{c}'"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(format!("bad escape at byte {}", self.pos)),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Re-decode multi-byte UTF-8 sequence.
                    let start = self.pos - 1;
                    let len = utf8_len(c);
                    self.pos = start + len;
                    let chunk = self
                        .bytes
                        .get(start..start + len)
                        .ok_or("truncated utf-8")?;
                    s.push_str(std::str::from_utf8(chunk).map_err(|e| e.to_string())?);
                }
                None => return Err("unterminated string".to_string()),
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("invalid number '{text}' at byte {start}"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xf0..=0xf7 => 4,
        0xe0..=0xef => 3,
        0xc0..=0xdf => 2,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-1", "3.25", "\"hi\""] {
            let v = parse(src).unwrap();
            assert_eq!(parse(&v.compact()).unwrap(), v, "{src}");
        }
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x\ny"}], "c": null}"#).unwrap();
        assert_eq!(v.get("c"), Some(&Json::Null));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x\ny"));
    }

    #[test]
    fn pretty_roundtrip() {
        let mut obj = Json::obj();
        obj.set("scale", Json::from(0.003921568859368563f64));
        obj.set("zero_point", Json::from(128u32));
        obj.set("symmetric", Json::from(false));
        obj.set("names", Json::from(vec!["conv1", "fc"]));
        let text = obj.pretty();
        assert_eq!(parse(&text).unwrap(), obj);
    }

    #[test]
    fn aimet_style_bool_strings() {
        let v = parse(r#"{"is_symmetric": "True"}"#).unwrap();
        assert_eq!(v.get("is_symmetric").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("{} x").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let v = parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
        let v = parse("\"é直\"").unwrap();
        assert_eq!(v.as_str(), Some("é直"));
    }

    #[test]
    fn float_precision_roundtrip() {
        let x = 1.2345678901234567e-3f64;
        let v = Json::from(x);
        let back = parse(&v.compact()).unwrap().as_f64().unwrap();
        assert_eq!(back, x);
    }
}
