//! Model compression (the paper's second pillar: "a library of
//! state-of-the-art quantization *and compression* algorithms").
//!
//! Three pieces compose into the deployment path the AIMET paper and the
//! quantization white papers (Nagel et al. 2021, Krishnamoorthi 2018)
//! assume — compress, then quantize:
//!
//! * [`svd`] — spatial SVD for convs (k×k → k×1 + 1×k) and low-rank
//!   factorization for linears.
//! * [`prune`] — channel pruning with least-squares reconstruction of the
//!   consumer's weights on calibration activations.
//! * [`search`] — greedy per-layer compression-ratio selection against a
//!   MAC budget, with candidate scoring parallelized on the worker pool.
//!
//! [`apply_plan`] performs the joint surgery (prunes first, in topological
//! order, so each reconstruction sees the already-pruned upstream; then
//! SVD factorizations, which subsume whatever pruning left behind), and
//! [`compress_then_ptq`] chains straight into the fig 4.1 PTQ pipeline:
//! compress → BN fold → CLE → quantize.

pub mod amp;
pub mod prune;
pub mod search;
pub mod svd;

pub use amp::{
    amp_greedy_plan, set_all_weight_bws, set_layer_weight_bw, AmpOptions, AmpOutcome,
    BwCandidate,
};
pub use prune::{find_prune_candidates, prune_channels, PruneCandidate, PruneReport};
pub use search::{
    greedy_plan, CandidatePoint, CompressionKind, CompressionPlan, LayerChoice,
    LayerSensitivity, SearchOptions, SearchOutcome,
};
pub use svd::{svd_apply, svd_candidates, SvdReport};

use crate::graph::Graph;
use crate::ptq::{standard_ptq_pipeline, PtqOptions, PtqOutcome};
use crate::tensor::Tensor;

/// What [`apply_plan`] produced.
#[derive(Debug, Clone)]
pub struct CompressionResult {
    pub graph: Graph,
    pub plan: CompressionPlan,
    pub macs_before: u64,
    pub macs_after: u64,
    /// Human-readable trace of the per-layer surgery.
    pub log: Vec<String>,
}

impl CompressionResult {
    /// Achieved compressed/original MAC ratio.
    pub fn mac_ratio(&self) -> f64 {
        self.macs_after as f64 / self.macs_before.max(1) as f64
    }
}

/// Apply a list of per-layer choices to a copy of `g`. Channel prunes run
/// first in topological order (each consumer reconstruction then sees the
/// already-pruned upstream activations); SVD factorizations follow, also
/// in topological order, re-resolving every layer by name since the
/// replacements shift node indices.
///
/// With `reconstruct: false` only the *structure* is applied (sliced /
/// zero-filled weights, no calibration forwards, no Jacobi) — the result
/// has the exact MAC count of the real application at a fraction of the
/// cost, which is what the search's budget verification needs.
pub(crate) fn apply_choices(
    g: &Graph,
    choices: &[LayerChoice],
    calib: &[Tensor],
    input_shape: &[usize],
    reconstruct: bool,
) -> (Graph, Vec<String>) {
    let mut out = g.clone();
    let mut log = Vec::new();
    let topo = |layer: &str| g.find(layer).unwrap_or(usize::MAX);
    let mut prunes: Vec<&LayerChoice> = choices
        .iter()
        .filter(|c| c.kind == CompressionKind::ChannelPrune)
        .collect();
    prunes.sort_by_key(|c| topo(&c.layer));
    for c in prunes {
        let rep = if reconstruct {
            prune_channels(&mut out, &c.layer, c.ratio, calib)
        } else {
            prune::prune_channels_structural(&mut out, &c.layer, c.ratio)
        };
        match rep {
            Some(rep) => {
                let note = if reconstruct && !rep.refit && rep.kept < rep.total {
                    ", consumer unrefit (singular solve)"
                } else {
                    ""
                };
                log.push(format!(
                    "prune {}: kept {}/{} channels (ratio {:.3}){note}",
                    c.layer, rep.kept, rep.total, c.ratio
                ));
            }
            None => log.push(format!("prune {}: skipped (pattern vanished)", c.layer)),
        }
    }
    let mut svds: Vec<&LayerChoice> = choices
        .iter()
        .filter(|c| c.kind == CompressionKind::SpatialSvd)
        .collect();
    svds.sort_by_key(|c| topo(&c.layer));
    for c in svds {
        let rep = if reconstruct {
            svd_apply(&mut out, &c.layer, c.ratio, input_shape)
        } else {
            svd::svd_apply_structural(&mut out, &c.layer, c.ratio, input_shape)
        };
        match rep {
            Some(rep) => log.push(format!(
                "svd {}: rank {}/{} (ratio {:.3})",
                c.layer, rep.rank, rep.full_rank, c.ratio
            )),
            None => log.push(format!("svd {}: skipped (layer vanished)", c.layer)),
        }
    }
    (out, log)
}

/// Apply a [`CompressionPlan`] to `g`, returning the compressed graph plus
/// the exact before/after MAC counts.
pub fn apply_plan(
    g: &Graph,
    plan: &CompressionPlan,
    calib: &[Tensor],
    input_shape: &[usize],
) -> CompressionResult {
    let macs_before = g.macs(input_shape);
    let (graph, mut log) = apply_choices(g, &plan.choices, calib, input_shape, true);
    let macs_after = graph.macs(input_shape);
    log.push(format!(
        "macs {} -> {} ({:.1}% of original, target {:.1}%)",
        macs_before,
        macs_after,
        100.0 * macs_after as f64 / macs_before.max(1) as f64,
        100.0 * plan.target_ratio
    ));
    CompressionResult {
        graph,
        plan: plan.clone(),
        macs_before,
        macs_after,
        log,
    }
}

/// The composed deployment path: apply the compression plan, then run the
/// standard fig 4.1 PTQ pipeline (BN fold → CLE → quantizer placement →
/// range setting → bias correction) over the factored graph.
pub fn compress_then_ptq(
    g: &Graph,
    plan: &CompressionPlan,
    calib: &[Tensor],
    input_shape: &[usize],
    ptq: &PtqOptions,
) -> (CompressionResult, PtqOutcome) {
    let result = apply_plan(g, plan, calib, input_shape);
    let outcome = standard_ptq_pipeline(&result.graph, calib, ptq);
    (result, outcome)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::zoo;

    fn manual_plan(choices: Vec<(&str, CompressionKind, f32)>) -> CompressionPlan {
        CompressionPlan {
            target_ratio: 0.5,
            choices: choices
                .into_iter()
                .map(|(l, k, r)| LayerChoice {
                    layer: l.to_string(),
                    kind: k,
                    ratio: r,
                })
                .collect(),
        }
    }

    #[test]
    fn apply_plan_reduces_macs_and_preserves_shapes() {
        let g = zoo::build("mobimini", 21).unwrap();
        let ds = crate::data::SynthImageNet::new(22);
        let calib: Vec<Tensor> = (0..2).map(|i| ds.batch(i, 4).0).collect();
        let plan = manual_plan(vec![
            ("stem.conv", CompressionKind::ChannelPrune, 0.5),
            ("b2.pw", CompressionKind::SpatialSvd, 0.5),
            ("b3.pw", CompressionKind::ChannelPrune, 0.5),
        ]);
        let res = apply_plan(&g, &plan, &calib, &[1, 3, 32, 32]);
        assert!(res.macs_after < res.macs_before);
        // Factored nodes exist, original vanished.
        assert!(res.graph.find("b2.pw").is_none());
        assert!(res.graph.find("b2.pw.svd_v").is_some());
        assert!(res.graph.find("b2.pw.svd_h").is_some());
        // End-to-end shape preserved.
        let (x, _) = ds.batch(9, 2);
        assert_eq!(res.graph.forward(&x).shape(), g.forward(&x).shape());
        // Structure-only application (the search's MAC verifier) lands on
        // exactly the same cost.
        let (structural, _) = apply_choices(&g, &plan.choices, &calib, &[1, 3, 32, 32], false);
        assert_eq!(structural.macs(&[1, 3, 32, 32]), res.macs_after);
    }

    #[test]
    fn compress_then_ptq_produces_runnable_sim() {
        let g = zoo::build("mobimini", 23).unwrap();
        let ds = crate::data::SynthImageNet::new(24);
        let calib: Vec<Tensor> = (0..2).map(|i| ds.batch(i, 8).0).collect();
        let plan = manual_plan(vec![
            ("b1.pw", CompressionKind::ChannelPrune, 0.5),
            ("b3.pw", CompressionKind::SpatialSvd, 0.5),
        ]);
        let (res, out) =
            compress_then_ptq(&g, &plan, &calib, &[1, 3, 32, 32], &PtqOptions::default());
        assert!(res.macs_after < res.macs_before);
        // PTQ ran BN folding on the compressed graph.
        assert!(out
            .sim
            .graph
            .nodes
            .iter()
            .all(|n| n.op.kind() != "BatchNorm"));
        // The sim is a drop-in replacement with the original output shape.
        let (x, _) = ds.batch(5, 4);
        assert_eq!(out.sim.forward(&x).shape(), g.forward(&x).shape());
        // Compressed (factored) nodes carry parameter quantizers.
        let idx = out.sim.graph.find("b3.pw.svd_h").unwrap();
        assert!(out.sim.params[idx].is_some());
    }
}
