//! AIMET-style greedy compression-ratio selection.
//!
//! For every compressible layer and every candidate ratio, a single-layer
//! compressed copy of the model is built and scored — all candidates run
//! in parallel on the worker pool, the shape AIMET calls *sensitivity
//! analysis*. Selection then sweeps an eval-score floor downward over the
//! observed scores: at each floor every layer independently picks its
//! largest-saving candidate that still scores above the floor, and the
//! first floor whose estimated total MACs meets the target budget wins.
//! Per-layer savings are additive to first order, which is what makes the
//! greedy estimate sound; [`crate::compress::apply_plan`] recomputes the
//! exact MAC count after the joint application.

use super::prune::{find_prune_candidates, prune_channels};
use super::svd::{svd_apply, svd_candidates};
use crate::graph::Graph;
use crate::pool::parallel_map;
use crate::tensor::Tensor;

/// Which compression algorithm a choice uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CompressionKind {
    /// Spatial SVD (convs) / low-rank factorization (linears).
    SpatialSvd,
    /// Channel pruning with least-squares reconstruction.
    ChannelPrune,
}

impl CompressionKind {
    pub fn label(&self) -> &'static str {
        match self {
            CompressionKind::SpatialSvd => "svd",
            CompressionKind::ChannelPrune => "prune",
        }
    }
}

/// One selected per-layer compression.
#[derive(Debug, Clone)]
pub struct LayerChoice {
    pub layer: String,
    pub kind: CompressionKind,
    pub ratio: f32,
}

/// The output of the greedy search: what to compress and how much.
#[derive(Debug, Clone)]
pub struct CompressionPlan {
    /// Requested compressed/original MAC budget (e.g. 0.5).
    pub target_ratio: f32,
    pub choices: Vec<LayerChoice>,
}

/// One evaluated (kind, ratio) candidate of a layer's sensitivity curve.
#[derive(Debug, Clone)]
pub struct CandidatePoint {
    pub kind: CompressionKind,
    pub ratio: f32,
    /// Eval score of the model with only this layer compressed.
    pub score: f32,
    /// Whole-graph MACs of that single-layer-compressed model.
    pub macs: u64,
}

/// Per-layer sensitivity curve.
#[derive(Debug, Clone)]
pub struct LayerSensitivity {
    pub layer: String,
    pub points: Vec<CandidatePoint>,
}

/// Search configuration.
#[derive(Debug, Clone)]
pub struct SearchOptions {
    /// Compressed/original MAC budget to hit (0 < r < 1).
    pub target_ratio: f32,
    /// Per-layer candidate compression ratios to probe (all < 1.0; 1.0 is
    /// implicitly "leave the layer alone").
    pub candidate_ratios: Vec<f32>,
}

impl Default for SearchOptions {
    fn default() -> Self {
        SearchOptions {
            target_ratio: 0.5,
            candidate_ratios: vec![0.375, 0.5, 0.75],
        }
    }
}

/// The search result: the plan plus everything needed for reports.
#[derive(Debug, Clone)]
pub struct SearchOutcome {
    pub plan: CompressionPlan,
    pub sensitivity: Vec<LayerSensitivity>,
    pub base_score: f32,
    pub base_macs: u64,
    /// First-order greedy estimate of the compressed model's MACs (adds
    /// per-layer savings; optimistic when savings overlap).
    pub estimated_macs: u64,
    /// Exact MACs of the jointly-applied plan, verified during selection.
    pub achieved_macs: u64,
    /// The eval-score floor the selection settled on.
    pub score_floor: f32,
}

/// Run sensitivity analysis + greedy per-layer ratio selection.
///
/// `eval` scores a candidate graph (higher is better — the task metric);
/// it is called from pool workers, so it must be pure w.r.t. its input.
pub fn greedy_plan(
    g: &Graph,
    calib: &[Tensor],
    input_shape: &[usize],
    eval: &(dyn Fn(&Graph) -> f32 + Sync),
    opts: &SearchOptions,
) -> SearchOutcome {
    let base_macs = g.macs(input_shape);
    let base_score = eval(g);

    // Enumerate (layer, kind, ratio) candidates.
    let mut cands: Vec<(String, CompressionKind, f32)> = Vec::new();
    for name in svd_candidates(g) {
        for &r in &opts.candidate_ratios {
            cands.push((name.clone(), CompressionKind::SpatialSvd, r));
        }
    }
    for c in find_prune_candidates(g) {
        let name = g.nodes[c.producer].name.clone();
        for &r in &opts.candidate_ratios {
            cands.push((name.clone(), CompressionKind::ChannelPrune, r));
        }
    }

    // Evaluate every candidate in parallel: each builds a one-layer
    // compressed clone and scores it.
    let points: Vec<Option<(String, CandidatePoint)>> =
        parallel_map(cands.len(), 1, |i| {
            let (name, kind, ratio) = &cands[i];
            let mut g2 = g.clone();
            let applied = match kind {
                CompressionKind::SpatialSvd => {
                    svd_apply(&mut g2, name, *ratio, input_shape).is_some()
                }
                CompressionKind::ChannelPrune => {
                    prune_channels(&mut g2, name, *ratio, calib).is_some()
                }
            };
            if !applied {
                return None;
            }
            let macs = g2.macs(input_shape);
            if macs >= base_macs {
                // Not actually cheaper (tiny layer, rank floor) — useless
                // as a compression move.
                return None;
            }
            let score = eval(&g2);
            if !score.is_finite() {
                // A blown-up candidate (e.g. a degenerate refit) must not
                // poison the floor sweep.
                return None;
            }
            Some((
                name.clone(),
                CandidatePoint {
                    kind: *kind,
                    ratio: *ratio,
                    score,
                    macs,
                },
            ))
        });

    // Group into per-layer curves (insertion order = topological).
    let mut sensitivity: Vec<LayerSensitivity> = Vec::new();
    for (name, p) in points.into_iter().flatten() {
        match sensitivity.iter_mut().find(|s| s.layer == name) {
            Some(s) => s.points.push(p),
            None => sensitivity.push(LayerSensitivity {
                layer: name,
                points: vec![p],
            }),
        }
    }

    // Selection: sweep the score floor downward over observed scores.
    let target = (opts.target_ratio as f64 * base_macs as f64) as u64;
    let mut floors: Vec<f32> = sensitivity
        .iter()
        .flat_map(|s| s.points.iter().map(|p| p.score))
        .collect();
    floors.push(base_score);
    floors.sort_by(|a, b| b.total_cmp(a));
    floors.dedup();

    let select = |floor: f32| -> (Vec<LayerChoice>, u64) {
        let mut choices = Vec::new();
        let mut saved = 0u64;
        for s in &sensitivity {
            if let Some(best) = s
                .points
                .iter()
                .filter(|p| p.score >= floor)
                .max_by_key(|p| base_macs - p.macs)
            {
                choices.push(LayerChoice {
                    layer: s.layer.clone(),
                    kind: best.kind,
                    ratio: best.ratio,
                });
                saved += base_macs - best.macs;
            }
        }
        (choices, base_macs.saturating_sub(saved))
    };

    // Per-layer savings overlap when a prune also shrinks a later chosen
    // layer, so the additive estimate is a lower bound on the joint MAC
    // count. Floors whose *estimate* misses the budget are skipped
    // outright; the first floor whose estimate fits is verified against
    // the exact MACs of the jointly-applied plan (structure-only: same
    // shapes, no reconstruction cost), descending further if the overlap
    // pushed it over budget.
    let actual_macs = |choices: &[LayerChoice]| -> u64 {
        super::apply_choices(g, choices, calib, input_shape, false)
            .0
            .macs(input_shape)
    };
    let mut chosen = None;
    for &floor in &floors {
        let (choices, est) = select(floor);
        if est > target {
            continue;
        }
        let actual = actual_macs(&choices);
        if actual <= target {
            chosen = Some((floor, choices, est, actual));
            break;
        }
    }
    let (score_floor, choices, estimated_macs, achieved_macs) = chosen.unwrap_or_else(|| {
        // Even maximum compression misses the budget: take it anyway.
        let (choices, est) = select(f32::NEG_INFINITY);
        let actual = actual_macs(&choices);
        (f32::NEG_INFINITY, choices, est, actual)
    });

    SearchOutcome {
        plan: CompressionPlan {
            target_ratio: opts.target_ratio,
            choices,
        },
        sensitivity,
        base_score,
        base_macs,
        estimated_macs,
        achieved_macs,
        score_floor,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::zoo;

    #[test]
    fn greedy_meets_budget_on_mobimini() {
        let g = zoo::build("mobimini", 11).unwrap();
        let ds = crate::data::SynthImageNet::new(12);
        let calib: Vec<Tensor> = (0..2).map(|i| ds.batch(i, 4).0).collect();
        let (xe, _) = ds.batch(100, 8);
        // A cheap smooth proxy score: negative output distortion vs FP32.
        let y0 = g.forward(&xe);
        let eval = move |g2: &Graph| -> f32 { -g2.forward(&xe).sq_err(&y0) };
        let opts = SearchOptions {
            target_ratio: 0.5,
            candidate_ratios: vec![0.5, 0.75],
        };
        let out = greedy_plan(&g, &calib, &[1, 3, 32, 32], &eval, &opts);
        assert!(!out.plan.choices.is_empty());
        assert!(
            out.achieved_macs as f64 <= 0.5 * out.base_macs as f64,
            "achieved {} vs base {}",
            out.achieved_macs,
            out.base_macs
        );
        assert!(out.estimated_macs <= out.achieved_macs);
        // Sensitivity curves are grouped per layer with ≤ 2 kinds × 2
        // ratios each.
        for s in &out.sensitivity {
            assert!(!s.points.is_empty() && s.points.len() <= 4, "{}", s.layer);
        }
    }
}
