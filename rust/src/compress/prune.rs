//! Channel pruning with least-squares weight reconstruction.
//!
//! A *producer* (Conv2d or Linear) drops its lowest-L2 output channels;
//! every per-channel node on the single-consumer chain between it and the
//! next weighted *consumer* (BatchNorm vectors, depthwise filters) is
//! sliced to match, and the consumer's weights are then re-fit by ridge
//! least squares against its **original** outputs on calibration
//! activations — the standard channel-pruning reconstruction (He et al.,
//! ICCV'17) restated on this graph IR. Keep-ratio 1.0 is an exact no-op so
//! pruning composes losslessly with the rest of the pipeline when a layer
//! is left uncompressed.

use crate::graph::{Graph, Op};
use crate::tensor::{im2col, Tensor};

/// A prunable producer→consumer pattern: `chain` is the (possibly empty)
/// run of per-channel/pass-through nodes between them.
#[derive(Debug, Clone)]
pub struct PruneCandidate {
    pub producer: usize,
    pub chain: Vec<usize>,
    pub consumer: usize,
}

/// True for ops that carry a channel dimension straight through (possibly
/// with per-channel parameters that must be sliced alongside the producer).
fn chain_passthrough(op: &Op) -> bool {
    matches!(
        op,
        Op::BatchNorm { .. }
            | Op::Relu
            | Op::Relu6
            | Op::MaxPool2
            | Op::AvgPool2
            | Op::GlobalAvgPool
            | Op::Upsample2
            | Op::Flatten
            | Op::DepthwiseConv2d { .. }
    )
}

fn is_producer(op: &Op) -> bool {
    matches!(op, Op::Conv2d { .. } | Op::Linear { .. })
}

/// Walk the single-consumer chain from `producer`; `None` when the pattern
/// does not apply (branching, Add/Concat/Lstm consumers, graph output
/// inside the chain).
fn candidate_from(g: &Graph, producer: usize) -> Option<PruneCandidate> {
    if !is_producer(&g.nodes[producer].op) {
        return None;
    }
    let mut chain = Vec::new();
    let mut cur = producer;
    loop {
        if cur == g.output {
            // Pruning would change the model's output channels.
            return None;
        }
        let cons = g.consumers(cur);
        if cons.len() != 1 {
            return None;
        }
        let next = cons[0];
        let op = &g.nodes[next].op;
        if is_producer(op) {
            return Some(PruneCandidate {
                producer,
                chain,
                consumer: next,
            });
        }
        if !chain_passthrough(op) {
            return None;
        }
        chain.push(next);
        cur = next;
    }
}

/// All prunable producers, in topological order.
pub fn find_prune_candidates(g: &Graph) -> Vec<PruneCandidate> {
    (0..g.nodes.len())
        .filter_map(|i| candidate_from(g, i))
        .collect()
}

/// What a pruning application did.
#[derive(Debug, Clone)]
pub struct PruneReport {
    pub kept: usize,
    pub total: usize,
    /// Whether the consumer's weights were actually least-squares
    /// reconstructed (false after a singular solve or in shape-only mode —
    /// the sliced weights are then kept unrefit, which is valid but
    /// strictly worse, and worth surfacing in logs).
    pub refit: bool,
}

/// Keep the `keep` per-index entries of a flat per-channel vector.
fn slice_vec(v: &[f32], keep: &[usize]) -> Vec<f32> {
    keep.iter().map(|&c| v[c]).collect()
}

/// Keep rows (axis 0 blocks) of a weight tensor.
fn slice_axis0(w: &Tensor, keep: &[usize]) -> Tensor {
    let o = w.dim(0);
    let inner = w.len() / o;
    let mut data = Vec::with_capacity(keep.len() * inner);
    for &c in keep {
        data.extend_from_slice(&w.data()[c * inner..(c + 1) * inner]);
    }
    let mut shape = w.shape().to_vec();
    shape[0] = keep.len();
    Tensor::new(&shape, data)
}

/// Keep axis-1 blocks of a weight tensor, where each kept channel owns
/// `mult` consecutive entries along axis 1 (mult > 1 when a Flatten sits
/// between a conv producer and a Linear consumer).
fn slice_axis1(w: &Tensor, keep: &[usize], mult: usize) -> Tensor {
    let o = w.dim(0);
    let c = w.dim(1);
    let inner = w.len() / (o * c);
    let kept_c = keep.len() * mult;
    let mut data = Vec::with_capacity(o * kept_c * inner);
    for oi in 0..o {
        for &ch in keep {
            for m in 0..mult {
                let src = (oi * c + ch * mult + m) * inner;
                data.extend_from_slice(&w.data()[src..src + inner]);
            }
        }
    }
    let mut shape = w.shape().to_vec();
    shape[1] = kept_c;
    Tensor::new(&shape, data)
}

/// Solve `G · X = B` for symmetric positive-definite-ish `G` [n,n] with
/// multi-column RHS `B` [n, k], by Gaussian elimination with partial
/// pivoting. Returns `None` on (numerical) singularity.
fn solve_multi(g: &mut [f32], n: usize, b: &mut [f32], k: usize) -> Option<()> {
    for col in 0..n {
        // Pivot.
        let mut piv = col;
        for r in col + 1..n {
            if g[r * n + col].abs() > g[piv * n + col].abs() {
                piv = r;
            }
        }
        if g[piv * n + col].abs() < 1e-20 {
            return None;
        }
        if piv != col {
            for j in 0..n {
                g.swap(col * n + j, piv * n + j);
            }
            for j in 0..k {
                b.swap(col * k + j, piv * k + j);
            }
        }
        let d = g[col * n + col];
        for r in 0..n {
            if r == col {
                continue;
            }
            let f = g[r * n + col] / d;
            if f == 0.0 {
                continue;
            }
            for j in col..n {
                g[r * n + j] -= f * g[col * n + j];
            }
            for j in 0..k {
                b[r * k + j] -= f * b[col * k + j];
            }
        }
    }
    for r in 0..n {
        let d = g[r * n + r];
        for j in 0..k {
            b[r * k + j] /= d;
        }
    }
    Some(())
}

/// NCHW → [C, N·H·W] matricization matching [`im2col`]'s column order.
fn nchw_to_channel_major(y: &Tensor) -> Tensor {
    let (n, c) = (y.dim(0), y.dim(1));
    let inner: usize = y.shape()[2..].iter().product();
    let l = n * inner;
    let mut out = vec![0.0f32; c * l];
    let yd = y.data();
    for ni in 0..n {
        for ci in 0..c {
            let src = (ni * c + ci) * inner;
            let dst = ci * l + ni * inner;
            out[dst..dst + inner].copy_from_slice(&yd[src..src + inner]);
        }
    }
    Tensor::new(&[c, l], out)
}

/// Prune the lowest-magnitude output channels of producer `name` down to
/// `keep_ratio`, then reconstruct the downstream consumer's weights and
/// bias by ridge least squares on `calib`. Returns `None` when `name` is
/// not a prunable producer. A `keep_ratio ≥ 1` leaves the graph
/// bit-identical.
pub fn prune_channels(
    g: &mut Graph,
    name: &str,
    keep_ratio: f32,
    calib: &[Tensor],
) -> Option<PruneReport> {
    prune_impl(g, name, keep_ratio, calib, true)
}

/// Shape-only variant for MAC accounting: performs the structural slicing
/// (producer rows, chain params, consumer input axis) but skips the
/// calibration forwards and the least-squares refit. The resulting graph
/// has exactly the MAC count of a real prune.
pub(crate) fn prune_channels_structural(
    g: &mut Graph,
    name: &str,
    keep_ratio: f32,
) -> Option<PruneReport> {
    prune_impl(g, name, keep_ratio, &[], false)
}

fn prune_impl(
    g: &mut Graph,
    name: &str,
    keep_ratio: f32,
    calib: &[Tensor],
    reconstruct: bool,
) -> Option<PruneReport> {
    let producer = g.find(name)?;
    let cand = candidate_from(g, producer)?;
    let total = g.nodes[producer].op.out_channels()?;
    let keep_n = ((keep_ratio * total as f32).round() as usize).clamp(1, total);
    if keep_n >= total {
        return Some(PruneReport {
            kept: total,
            total,
            refit: true,
        });
    }

    // Linear consumers may see `mult` features per producer channel
    // (Flatten between a spatial producer and the head).
    let consumer_in = match &g.nodes[cand.consumer].op {
        Op::Conv2d { weight, .. } => weight.dim(1),
        Op::Linear { weight, .. } => weight.dim(1),
        _ => unreachable!(),
    };
    if consumer_in % total != 0 {
        return None;
    }
    let mult = consumer_in / total;

    // Channel importance: squared L2 of each producer output-channel slice.
    let w = g.nodes[producer].op.weight()?;
    let inner = w.len() / total;
    let mut norms: Vec<(f32, usize)> = (0..total)
        .map(|c| {
            let s: f32 = w.data()[c * inner..(c + 1) * inner]
                .iter()
                .map(|v| v * v)
                .sum();
            (s, c)
        })
        .collect();
    norms.sort_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
    let mut keep: Vec<usize> = norms[..keep_n].iter().map(|&(_, c)| c).collect();
    keep.sort_unstable();

    // Original consumer outputs — the least-squares target. Only the
    // prefix up to the consumer is needed; nothing downstream matters.
    let y_orig: Vec<Tensor> = if reconstruct {
        calib
            .iter()
            .map(|b| {
                g.forward_prefix(b, cand.consumer)
                    .pop()
                    .expect("prefix includes the consumer")
            })
            .collect()
    } else {
        Vec::new()
    };

    // Structural surgery: producer rows, chain per-channel params, consumer
    // input axis.
    {
        let op = &mut g.nodes[producer].op;
        let new_w = slice_axis0(op.weight().unwrap(), &keep);
        *op.weight_mut().unwrap() = new_w;
        let b = op.bias_mut().unwrap();
        *b = slice_vec(b, &keep);
    }
    for &ci in &cand.chain {
        match &mut g.nodes[ci].op {
            Op::BatchNorm {
                gamma,
                beta,
                mean,
                var,
                ..
            } => {
                *gamma = slice_vec(gamma, &keep);
                *beta = slice_vec(beta, &keep);
                *mean = slice_vec(mean, &keep);
                *var = slice_vec(var, &keep);
            }
            Op::DepthwiseConv2d { weight, bias, .. } => {
                *weight = slice_axis0(weight, &keep);
                *bias = slice_vec(bias, &keep);
            }
            _ => {}
        }
    }
    {
        let op = &mut g.nodes[cand.consumer].op;
        let new_w = slice_axis1(op.weight().unwrap(), &keep, mult);
        *op.weight_mut().unwrap() = new_w;
    }

    let mut refit = false;
    if !reconstruct || calib.is_empty() {
        return Some(PruneReport {
            kept: keep_n,
            total,
            refit,
        });
    }

    // Reconstruction: fit [W'|b'] minimizing ‖W'·A + b' − Y‖² + λ‖·‖²
    // over the calibration set, via the normal equations accumulated
    // batch-by-batch (A is the consumer's post-pruning input in matrix
    // form, with a ones row appended for the bias).
    let (k_dim, spec_kh_kw) = match &g.nodes[cand.consumer].op {
        Op::Conv2d { weight, spec, .. } => (
            weight.dim(1) * weight.dim(2) * weight.dim(3),
            Some((weight.dim(2), weight.dim(3), *spec)),
        ),
        Op::Linear { weight, .. } => (weight.dim(1), None),
        _ => unreachable!(),
    };
    let n_aug = k_dim + 1;
    let mut gram = vec![0.0f32; n_aug * n_aug];
    let mut corr = vec![0.0f32; 0];
    let mut o_c = 0usize;
    for (batch, y) in calib.iter().zip(&y_orig) {
        let x_in = match g.nodes[cand.consumer].inputs[0] {
            crate::graph::Input::Graph => batch.clone(),
            crate::graph::Input::Node(j) => g
                .forward_prefix(batch, j)
                .pop()
                .expect("prefix includes the consumer input"),
        };
        let (a_mat, y_mat) = match spec_kh_kw {
            Some((kh, kw, spec)) => (im2col(&x_in, kh, kw, spec), nchw_to_channel_major(y)),
            None => {
                let f = *x_in.shape().last().unwrap();
                let lead = x_in.len() / f;
                (
                    x_in.reshape(&[lead, f]).transpose2(),
                    y.reshape(&[lead, y.len() / lead]).transpose2(),
                )
            }
        };
        o_c = y_mat.dim(0);
        let l = a_mat.dim(1);
        // Augment with the ones row.
        let mut a_aug = a_mat.into_data();
        a_aug.extend(std::iter::repeat(1.0f32).take(l));
        let a_aug = Tensor::new(&[n_aug, l], a_aug);
        let gb = crate::tensor::matmul_a_bt(&a_aug, &a_aug);
        for (acc, v) in gram.iter_mut().zip(gb.data()) {
            *acc += v;
        }
        let cb = crate::tensor::matmul_a_bt(&y_mat, &a_aug); // [O_c, K+1]
        if corr.is_empty() {
            corr = vec![0.0f32; o_c * n_aug];
        }
        for (acc, v) in corr.iter_mut().zip(cb.data()) {
            *acc += v;
        }
    }
    // Ridge term keeps the solve well-posed on short calibration sets.
    let trace: f32 = (0..n_aug).map(|i| gram[i * n_aug + i]).sum();
    let lambda = 1e-6 * trace / n_aug as f32 + 1e-8;
    for i in 0..n_aug {
        gram[i * n_aug + i] += lambda;
    }
    // RHS as [K+1, O_c] (= Cᵀ).
    let mut rhs = vec![0.0f32; n_aug * o_c];
    for oi in 0..o_c {
        for kk in 0..n_aug {
            rhs[kk * o_c + oi] = corr[oi * n_aug + kk];
        }
    }
    if solve_multi(&mut gram, n_aug, &mut rhs, o_c).is_some() {
        let op = &mut g.nodes[cand.consumer].op;
        let shape = op.weight().unwrap().shape().to_vec();
        let mut new_w = vec![0.0f32; k_dim * o_c];
        for oi in 0..o_c {
            for kk in 0..k_dim {
                new_w[oi * k_dim + kk] = rhs[kk * o_c + oi];
            }
        }
        *op.weight_mut().unwrap() = Tensor::new(&shape, new_w);
        let bias = op.bias_mut().unwrap();
        for (oi, b) in bias.iter_mut().enumerate() {
            *b = rhs[k_dim * o_c + oi];
        }
        refit = true;
    }
    // On a singular solve the sliced weights are kept as-is — still a
    // valid (just unrefit) pruned model; `refit: false` surfaces it.
    Some(PruneReport {
        kept: keep_n,
        total,
        refit,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Input;
    use crate::rng::Rng;
    use crate::tensor::Conv2dSpec;
    use crate::zoo;

    fn conv_pair(rng: &mut Rng) -> Graph {
        let mut g = Graph::new();
        g.push(
            "c1",
            Op::Conv2d {
                weight: Tensor::randn(rng, &[8, 3, 3, 3], 0.4),
                bias: rng.normal_vec(8, 0.1),
                spec: Conv2dSpec::same(3),
            },
        );
        g.push("relu", Op::Relu);
        g.push(
            "c2",
            Op::Conv2d {
                weight: Tensor::randn(rng, &[5, 8, 1, 1], 0.4),
                bias: rng.normal_vec(5, 0.1),
                spec: Conv2dSpec::unit(),
            },
        );
        g.push("gap", Op::GlobalAvgPool);
        g
    }

    #[test]
    fn keep_ratio_one_is_bit_identical() {
        let mut rng = Rng::new(1);
        let g0 = conv_pair(&mut rng);
        let mut g = g0.clone();
        let calib = vec![Tensor::randn(&mut rng, &[2, 3, 6, 6], 1.0)];
        let rep = prune_channels(&mut g, "c1", 1.0, &calib).unwrap();
        assert_eq!(rep.kept, rep.total);
        let x = Tensor::randn(&mut rng, &[1, 3, 6, 6], 1.0);
        assert_eq!(g.forward(&x), g0.forward(&x));
    }

    #[test]
    fn pruning_shrinks_and_reconstruction_beats_plain_slice() {
        let mut rng = Rng::new(2);
        let g0 = conv_pair(&mut rng);
        let calib: Vec<Tensor> = (0..3)
            .map(|_| Tensor::randn(&mut rng, &[4, 3, 6, 6], 1.0))
            .collect();
        let x = Tensor::randn(&mut rng, &[2, 3, 6, 6], 1.0);
        let y0 = g0.forward(&x);

        let mut pruned = g0.clone();
        let rep = prune_channels(&mut pruned, "c1", 0.5, &calib).unwrap();
        assert!(rep.refit, "healthy calibration must refit the consumer");
        assert_eq!(pruned.nodes[0].op.out_channels(), Some(4));
        assert_eq!(
            pruned.nodes[2].op.weight().unwrap().shape(),
            &[5, 4, 1, 1]
        );
        // Output shape unchanged.
        let yp = pruned.forward(&x);
        assert_eq!(yp.shape(), y0.shape());

        // Reconstruction should beat naive slicing (same keep set, no
        // least-squares refit).
        let mut naive = g0.clone();
        {
            // Re-derive the same keep set.
            let w = naive.nodes[0].op.weight().unwrap().clone();
            let inner = w.len() / 8;
            let mut norms: Vec<(f32, usize)> = (0..8)
                .map(|c| {
                    (
                        w.data()[c * inner..(c + 1) * inner]
                            .iter()
                            .map(|v| v * v)
                            .sum(),
                        c,
                    )
                })
                .collect();
            norms.sort_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
            let mut keep: Vec<usize> = norms[..4].iter().map(|&(_, c)| c).collect();
            keep.sort_unstable();
            let op = &mut naive.nodes[0].op;
            let new_w = slice_axis0(op.weight().unwrap(), &keep);
            *op.weight_mut().unwrap() = new_w;
            let b = op.bias_mut().unwrap();
            *b = slice_vec(b, &keep);
            let op = &mut naive.nodes[2].op;
            let new_w = slice_axis1(op.weight().unwrap(), &keep, 1);
            *op.weight_mut().unwrap() = new_w;
        }
        let e_recon = yp.sq_err(&y0);
        let e_naive = naive.forward(&x).sq_err(&y0);
        assert!(
            e_recon < e_naive,
            "reconstruction {e_recon} should beat naive slice {e_naive}"
        );
    }

    #[test]
    fn candidates_cross_bn_relu_depthwise_chains() {
        let g = zoo::build("mobimini", 3).unwrap();
        let cands = find_prune_candidates(&g);
        let names: Vec<&str> = cands
            .iter()
            .map(|c| g.nodes[c.producer].name.as_str())
            .collect();
        // stem.conv reaches b1.dw's pointwise consumer through bn + relu6 +
        // the depthwise filter; the final pointwise reaches fc through gap.
        assert!(names.contains(&"stem.conv"), "{names:?}");
        assert!(names.contains(&"b3.pw"), "{names:?}");
        // fc is the output — not prunable.
        assert!(!names.contains(&"fc"));
    }

    #[test]
    fn prune_through_depthwise_keeps_mobimini_runnable() {
        let mut rng = Rng::new(4);
        let mut g = zoo::build("mobimini", 5).unwrap();
        let calib = vec![Tensor::randn(&mut rng, &[4, 3, 32, 32], 1.0)];
        let rep = prune_channels(&mut g, "b1.pw", 0.5, &calib).unwrap();
        assert_eq!(rep.kept, 16);
        // The depthwise in the chain shrank with the producer.
        let dw = g.find("b2.dw").unwrap();
        assert_eq!(g.nodes[dw].op.out_channels(), Some(16));
        let y = g.forward(&Tensor::randn(&mut rng, &[1, 3, 32, 32], 1.0));
        assert_eq!(y.shape(), &[1, 10]);
    }

    #[test]
    fn add_consumers_are_rejected() {
        let mut rng = Rng::new(6);
        let mut g = Graph::new();
        let c1 = g.push(
            "c1",
            Op::Conv2d {
                weight: Tensor::randn(&mut rng, &[4, 4, 3, 3], 0.3),
                bias: vec![0.0; 4],
                spec: Conv2dSpec::same(3),
            },
        );
        g.push_with("add", Op::Add, vec![Input::Node(c1), Input::Graph]);
        g.push(
            "c2",
            Op::Conv2d {
                weight: Tensor::randn(&mut rng, &[4, 4, 1, 1], 0.3),
                bias: vec![0.0; 4],
                spec: Conv2dSpec::unit(),
            },
        );
        assert!(find_prune_candidates(&g)
            .iter()
            .all(|c| c.producer != c1));
    }
}
