//! AMP-style greedy per-layer weight bit-width search (W4A8).
//!
//! Mirrors the sensitivity-curve sweep of [`super::search`], but over
//! *weight bit-widths* instead of MAC ratios: every weighted layer is
//! scored with only its own weights dropped to the low bit-width (4 by
//! default) while activations and every other layer stay at the W8A8
//! base. Selection then sweeps an eval-score floor downward over the
//! observed scores; at each floor every layer whose low-bit score clears
//! the floor drops, and the first floor whose estimated packed-weight
//! bytes meet the budget is verified against an exact joint lowering.
//!
//! A nibble-packed int4 K-panel is exactly half its 8-bit byte-panel size
//! (two weights per byte, same `GEMM_MR` row padding), so the per-layer
//! saving is layer-local and the additive greedy estimate is exact — the
//! verification pass only guards the rare one-tailed weight tensor that
//! falls back to byte panels.
//!
//! The final mixed-precision model applies AdaRound to the layers that
//! dropped (rounding error dominates at 4 bits), freezes those encodings,
//! and re-runs the standard range-setting steps for everything else.

use std::collections::BTreeMap;

use crate::engine;
use crate::graph::{Graph, Op};
use crate::pool::parallel_map;
use crate::ptq::{
    apply_adaround_for_layers, set_activation_ranges, set_weight_ranges,
    standard_ptq_pipeline, PtqOptions,
};
use crate::quant::{per_channel_weight_encodings, weight_encoding, Quantizer};
use crate::quantsim::{set_and_freeze_param_encodings, QuantizationSimModel};
use crate::tensor::Tensor;

/// Search configuration for the mixed-precision bit-width search.
#[derive(Debug, Clone)]
pub struct AmpOptions {
    /// Packed-weight-byte budget relative to the all-8-bit engine lowering
    /// (0 < r < 1): 0.6 asks for a >= 40% packed-byte reduction.
    pub weight_budget: f32,
    /// Low weight bit-width candidate offered to every layer (the high
    /// candidate is the baseline `ptq.qp.param_bw`, normally 8).
    pub low_bw: u32,
    /// Run AdaRound on the dropped layers before the final joint
    /// simulation.
    pub adaround_low_bw_layers: bool,
}

impl Default for AmpOptions {
    fn default() -> Self {
        AmpOptions {
            weight_budget: 0.6,
            low_bw: 4,
            adaround_low_bw_layers: true,
        }
    }
}

/// One layer's low-bit sensitivity point.
#[derive(Debug, Clone)]
pub struct BwCandidate {
    pub layer: String,
    /// Eval score with only this layer's weights at the low bit-width.
    pub score: f32,
    /// Packed bytes the layer occupies at the baseline width.
    pub bytes_base: usize,
}

/// The search result: per-layer bit-widths plus everything needed for
/// reports, and the final mixed-precision sim ready for [`engine::lower`].
#[derive(Clone)]
pub struct AmpOutcome {
    /// Chosen weight bit-width for every weighted candidate layer.
    pub bws: BTreeMap<String, u32>,
    pub sensitivity: Vec<BwCandidate>,
    pub base_score: f32,
    /// Packed weight bytes of the all-8-bit lowered baseline.
    pub base_bytes: usize,
    /// First-order greedy estimate (additive per-layer halvings).
    pub estimated_bytes: usize,
    /// Exact packed bytes of the final lowered mixed-precision model.
    pub achieved_bytes: usize,
    /// The eval-score floor the selection settled on.
    pub score_floor: f32,
    /// Eval score of the final mixed-precision sim.
    pub final_score: f32,
    /// `final_score - base_score` (the acceptance bar is >= -1 pt).
    pub eval_delta: f32,
    /// Final mixed-precision sim: AdaRound'ed low-bit layers with frozen
    /// encodings, standard range setting elsewhere.
    pub sim: QuantizationSimModel,
}

/// Drop one layer's weight quantizer to `bw`, recomputing its encodings
/// from the current graph weights (mirrors the param branch of
/// `compute_encodings`, touching nothing else). Returns false for layers
/// without a param slot.
pub fn set_layer_weight_bw(sim: &mut QuantizationSimModel, name: &str, bw: u32) -> bool {
    if !sim.set_param_bw(name, bw) {
        return false;
    }
    let Some(idx) = sim.graph.find(name) else {
        return false;
    };
    let Some(w) = sim.graph.nodes[idx].op.weight() else {
        return false;
    };
    let Some(slot) = &mut sim.params[idx] else {
        return false;
    };
    slot.quantizer = Some(if slot.per_channel {
        Quantizer::per_channel(
            per_channel_weight_encodings(w, slot.scheme, slot.bw, slot.symmetric, 0),
            0,
        )
    } else {
        Quantizer::per_tensor(weight_encoding(w, slot.scheme, slot.bw, slot.symmetric))
    });
    sim.invalidate_weight_cache();
    true
}

/// Drop EVERY weighted layer's quantizer to `bw` — the forced all-low-bit
/// configuration `scripts/ci.sh` re-runs the engine suites under. Returns
/// how many layers changed.
pub fn set_all_weight_bws(sim: &mut QuantizationSimModel, bw: u32) -> usize {
    let names: Vec<String> = sim
        .graph
        .nodes
        .iter()
        .enumerate()
        .filter(|(i, n)| {
            matches!(
                n.op,
                Op::Conv2d { .. } | Op::DepthwiseConv2d { .. } | Op::Linear { .. }
            ) && sim.params[*i].is_some()
        })
        .map(|(_, n)| n.name.clone())
        .collect();
    names
        .iter()
        .filter(|name| set_layer_weight_bw(sim, name, bw))
        .count()
}

/// Run the sensitivity sweep + greedy per-layer bit-width selection.
///
/// `eval` scores a candidate sim (higher is better — the task metric); it
/// is called from pool workers, so it must be pure w.r.t. its input.
pub fn amp_greedy_plan(
    g: &Graph,
    calib: &[Tensor],
    eval: &(dyn Fn(&QuantizationSimModel) -> f32 + Sync),
    ptq: &PtqOptions,
    opts: &AmpOptions,
) -> Result<AmpOutcome, String> {
    // W8A8 baseline: the exact model the budget is measured against.
    let base_sim = standard_ptq_pipeline(g, calib, ptq).sim;
    let base_score = eval(&base_sim);
    let base_qm = engine::lower(&base_sim)?;
    let base_bytes = base_qm.packed_weight_bytes();
    let layer_bytes: BTreeMap<String, usize> = base_qm
        .weight_layers()
        .into_iter()
        .map(|(name, _bw, bytes)| (name, bytes))
        .collect();

    // Candidates: weighted single-matrix layers. LSTMs stay at the
    // baseline width (the engine keeps them f32 anyway).
    let cands: Vec<String> = base_sim
        .graph
        .nodes
        .iter()
        .enumerate()
        .filter(|(i, n)| {
            matches!(
                n.op,
                Op::Conv2d { .. } | Op::DepthwiseConv2d { .. } | Op::Linear { .. }
            ) && base_sim.params[*i].is_some()
        })
        .map(|(_, n)| n.name.clone())
        .collect();

    let low_bw = opts.low_bw;
    let points: Vec<Option<BwCandidate>> = parallel_map(cands.len(), 1, |i| {
        let name = &cands[i];
        let mut sim = base_sim.clone();
        if !set_layer_weight_bw(&mut sim, name, low_bw) {
            return None;
        }
        let score = eval(&sim);
        if !score.is_finite() {
            // A blown-up candidate must not poison the floor sweep.
            return None;
        }
        Some(BwCandidate {
            layer: name.clone(),
            score,
            bytes_base: layer_bytes.get(name.as_str()).copied().unwrap_or(0),
        })
    });
    let sensitivity: Vec<BwCandidate> = points.into_iter().flatten().collect();

    // Selection: sweep the score floor downward over observed scores.
    let target = (opts.weight_budget as f64 * base_bytes as f64) as usize;
    let mut floors: Vec<f32> = sensitivity.iter().map(|c| c.score).collect();
    floors.push(base_score);
    floors.sort_by(|a, b| b.total_cmp(a));
    floors.dedup();

    let select = |floor: f32| -> (Vec<String>, usize) {
        let mut low = Vec::new();
        let mut bytes = base_bytes;
        for c in &sensitivity {
            if c.score >= floor {
                low.push(c.layer.clone());
                bytes -= c.bytes_base / 2;
            }
        }
        (low, bytes)
    };

    // Exact verification lowers a jointly-dropped clone of the base sim
    // (AdaRound never changes packed sizes, so it can wait until the
    // floor is settled) and measures real packed bytes.
    let verified_bytes = |low: &[String]| -> Result<usize, String> {
        let mut sim = base_sim.clone();
        for name in low {
            set_layer_weight_bw(&mut sim, name, low_bw);
        }
        Ok(engine::lower(&sim)?.packed_weight_bytes())
    };

    let mut chosen = None;
    for &floor in &floors {
        let (low, est) = select(floor);
        if est > target {
            continue;
        }
        let actual = verified_bytes(&low)?;
        if actual <= target {
            chosen = Some((floor, low, est));
            break;
        }
    }
    let (score_floor, low, estimated_bytes) = match chosen {
        Some(c) => c,
        None => {
            // Even all-low-bit misses the budget: take it anyway.
            let (low, est) = select(f32::NEG_INFINITY);
            (f32::NEG_INFINITY, low, est)
        }
    };

    // Final mixed-precision sim. Order matters: `set_param_bw` clears the
    // frozen flag, so widths are set *before* freezing the AdaRound
    // encodings; `compute_encodings` and the range-setting passes then
    // skip the frozen low-bit slots.
    let mut sim = if opts.adaround_low_bw_layers && !low.is_empty() {
        let bw_map: BTreeMap<String, u32> =
            low.iter().map(|n| (n.clone(), low_bw)).collect();
        let ada = apply_adaround_for_layers(
            &base_sim.graph,
            ptq.qp,
            &ptq.cfg,
            calib,
            &ptq.adaround,
            &bw_map,
        );
        let mut sim = QuantizationSimModel::new(ada.graph, ptq.cfg.clone(), ptq.qp);
        for name in &low {
            sim.set_param_bw(name, low_bw);
        }
        set_and_freeze_param_encodings(&mut sim, &ada.param_encodings);
        sim
    } else {
        let mut sim =
            QuantizationSimModel::new(base_sim.graph.clone(), ptq.cfg.clone(), ptq.qp);
        for name in &low {
            sim.set_param_bw(name, low_bw);
        }
        sim
    };
    sim.compute_encodings(calib);
    set_weight_ranges(&mut sim, ptq.weight_scheme);
    set_activation_ranges(&mut sim, calib, ptq.act_scheme);

    let final_score = eval(&sim);
    let achieved_bytes = engine::lower(&sim)?.packed_weight_bytes();

    let mut bws: BTreeMap<String, u32> = cands
        .iter()
        .map(|n| (n.clone(), ptq.qp.param_bw))
        .collect();
    for name in &low {
        bws.insert(name.clone(), low_bw);
    }

    Ok(AmpOutcome {
        bws,
        sensitivity,
        base_score,
        base_bytes,
        estimated_bytes,
        achieved_bytes,
        score_floor,
        final_score,
        eval_delta: final_score - base_score,
        sim,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::zoo;

    #[test]
    fn amp_meets_byte_budget_on_mobimini() {
        let g = zoo::build("mobimini", 21).unwrap();
        let ds = crate::data::SynthImageNet::new(22);
        let calib: Vec<Tensor> = (0..2).map(|i| ds.batch(i, 4).0).collect();
        let (xe, _) = ds.batch(100, 8);
        // A cheap smooth proxy score: negative output distortion vs FP32.
        let y0 = g.forward(&xe);
        let eval = move |sim: &QuantizationSimModel| -> f32 {
            -sim.forward(&xe).sq_err(&y0)
        };
        let ptq = PtqOptions::default();
        let opts = AmpOptions {
            weight_budget: 0.6,
            // Keep the test cheap: rounding optimization is covered by the
            // AdaRound suite.
            adaround_low_bw_layers: false,
            ..AmpOptions::default()
        };
        let out = amp_greedy_plan(&g, &calib, &eval, &ptq, &opts).unwrap();
        assert!(!out.sensitivity.is_empty());
        assert!(
            out.achieved_bytes as f64 <= 0.6 * out.base_bytes as f64,
            "achieved {} vs base {}",
            out.achieved_bytes,
            out.base_bytes
        );
        // The additive estimate is exact for nibble-packed layers, so it
        // can only over-count savings when a layer falls back to bytes.
        assert!(out.estimated_bytes <= out.achieved_bytes + out.base_bytes / 10);
        // Every candidate layer got a width, and dropped layers are 4-bit.
        let dropped = out.bws.values().filter(|&&bw| bw == 4).count();
        assert!(dropped > 0, "expected at least one 4-bit layer");
        let qm = crate::engine::lower(&out.sim).unwrap();
        for (name, bw, _) in qm.weight_layers() {
            assert_eq!(out.bws.get(&name).copied().unwrap_or(8), bw, "{name}");
        }
    }
}
