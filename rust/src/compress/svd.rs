//! SVD-based structured compression.
//!
//! * **Spatial SVD** for `Conv2d`: the k_h×k_w kernel tensor is matricized
//!   as [I·k_h, O·k_w] and factored through its SVD; truncating to rank R
//!   replaces the conv with a k_h×1 conv (I→R, vertical stride/pad) feeding
//!   a 1×k_w conv (R→O, horizontal stride/pad). Function-preserving at full
//!   rank, MAC-reducing below it. For 1×1 convs this degenerates to the
//!   classic weight SVD (I→R→O pointwise pair).
//! * **Low-rank factorization** for `Linear`: W[O,F] ≈ U[O,R]·V[R,F], i.e.
//!   two stacked Linears.
//!
//! The SVD itself is a one-sided Jacobi (cyclic column orthogonalization):
//! deterministic, dependency-free, and accurate to float precision on the
//! small matrices that arise here (≤ a few hundred on a side), which is
//! what lets the rank-preserving factorization round-trip within 1e-4.

use crate::graph::{Graph, Input, Op};
use crate::tensor::{Conv2dSpec, Tensor};

/// Thin SVD of `m` ([rows, cols]): returns `(u, s, v)` with
/// `u` [rows, r], `s` [r] descending, `v` [cols, r], r = min(rows, cols),
/// such that `m ≈ u · diag(s) · vᵀ`.
pub fn svd_thin(m: &Tensor) -> (Tensor, Vec<f32>, Tensor) {
    assert_eq!(m.rank(), 2);
    let (rows, cols) = (m.dim(0), m.dim(1));
    if rows < cols {
        // SVD(Mᵀ) = (V, S, U).
        let (v, s, u) = svd_thin(&m.transpose2());
        return (u, s, v);
    }
    // Store the columns of M as contiguous rows (a = Mᵀ) so the Jacobi
    // rotations mix cache-friendly slices.
    let mut a: Vec<Vec<f64>> = (0..cols)
        .map(|j| (0..rows).map(|i| m.data()[i * cols + j] as f64).collect())
        .collect();
    // Accumulated right-rotation J (columns stored as rows): M·J = A_final.
    let mut v: Vec<Vec<f64>> = (0..cols)
        .map(|j| {
            let mut e = vec![0.0f64; cols];
            e[j] = 1.0;
            e
        })
        .collect();
    let tol = 1e-12f64;
    for _sweep in 0..40 {
        let mut rotated = false;
        for p in 0..cols {
            for q in p + 1..cols {
                let (mut alpha, mut beta, mut gamma) = (0.0f64, 0.0f64, 0.0f64);
                for i in 0..rows {
                    alpha += a[p][i] * a[p][i];
                    beta += a[q][i] * a[q][i];
                    gamma += a[p][i] * a[q][i];
                }
                if gamma.abs() <= tol * (alpha * beta).sqrt() || alpha == 0.0 || beta == 0.0 {
                    continue;
                }
                rotated = true;
                let zeta = (beta - alpha) / (2.0 * gamma);
                let t = zeta.signum() / (zeta.abs() + (1.0 + zeta * zeta).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..rows {
                    let (ap, aq) = (a[p][i], a[q][i]);
                    a[p][i] = c * ap - s * aq;
                    a[q][i] = s * ap + c * aq;
                }
                for i in 0..cols {
                    let (vp, vq) = (v[p][i], v[q][i]);
                    v[p][i] = c * vp - s * vq;
                    v[q][i] = s * vp + c * vq;
                }
            }
        }
        if !rotated {
            break;
        }
    }
    // Singular values are the column norms; sort descending.
    let mut order: Vec<usize> = (0..cols).collect();
    let norms: Vec<f64> = a
        .iter()
        .map(|col| col.iter().map(|x| x * x).sum::<f64>().sqrt())
        .collect();
    order.sort_by(|&i, &j| norms[j].total_cmp(&norms[i]));
    let r = cols; // rows >= cols here
    let mut u = vec![0.0f32; rows * r];
    let mut s = vec![0.0f32; r];
    let mut vt = vec![0.0f32; cols * r];
    for (jj, &j) in order.iter().enumerate() {
        let sigma = norms[j];
        s[jj] = sigma as f32;
        if sigma > 1e-30 {
            for i in 0..rows {
                u[i * r + jj] = (a[j][i] / sigma) as f32;
            }
        }
        for i in 0..cols {
            vt[i * r + jj] = v[j][i] as f32;
        }
    }
    (Tensor::new(&[rows, r], u), s, Tensor::new(&[cols, r], vt))
}

/// Rank that keeps the factored spatial-SVD MAC count within `ratio` of the
/// original conv's. Per output row the original costs `O·I·k_h·k_w·out_w`
/// MACs while the factor pair costs `R·(I·k_h·mid_w + O·k_w·out_w)` — the
/// vertical factor runs at the *input* width `mid_w` because horizontal
/// stride belongs to the second factor. `ratio ≥ 1` requests the lossless
/// full rank.
pub fn spatial_svd_rank(
    o: usize,
    i: usize,
    kh: usize,
    kw: usize,
    mid_w: usize,
    out_w: usize,
    ratio: f32,
) -> usize {
    let full = (i * kh).min(o * kw);
    if ratio >= 1.0 {
        return full;
    }
    let orig = (o * i * kh * kw * out_w) as f64;
    let per_rank = (i * kh * mid_w + o * kw * out_w) as f64;
    let r = (ratio as f64 * orig / per_rank).floor() as usize;
    r.clamp(1, full)
}

/// Rank that keeps `R·(O + F) ≤ ratio·O·F` for a Linear low-rank pair.
pub fn low_rank_linear_rank(o: usize, f: usize, ratio: f32) -> usize {
    let full = o.min(f);
    if ratio >= 1.0 {
        return full;
    }
    let r = (ratio as f64 * (o * f) as f64 / (o + f) as f64).floor() as usize;
    r.clamp(1, full)
}

/// Factor a conv weight [O,I,kh,kw] at `rank` into the vertical factor
/// [R,I,kh,1] and the horizontal factor [O,R,1,kw].
pub fn spatial_svd_factors(weight: &Tensor, rank: usize) -> (Tensor, Tensor) {
    let (o, i, kh, kw) = (weight.dim(0), weight.dim(1), weight.dim(2), weight.dim(3));
    let rows = i * kh;
    let cols = o * kw;
    // M[(i·kh + y), (o·kw + x)] = W[o, i, y, x].
    let mut m = vec![0.0f32; rows * cols];
    let wd = weight.data();
    for oi in 0..o {
        for ii in 0..i {
            for y in 0..kh {
                for x in 0..kw {
                    m[(ii * kh + y) * cols + (oi * kw + x)] = wd[((oi * i + ii) * kh + y) * kw + x];
                }
            }
        }
    }
    let (u, s, v) = svd_thin(&Tensor::new(&[rows, cols], m));
    let r = rank.min(s.len()).max(1);
    let full = s.len();
    // Split Σ evenly so both factors stay well-scaled for quantization.
    let mut wv = vec![0.0f32; r * i * kh];
    for rr in 0..r {
        let sq = s[rr].max(0.0).sqrt();
        for ii in 0..i {
            for y in 0..kh {
                wv[(rr * i + ii) * kh + y] = u.data()[(ii * kh + y) * full + rr] * sq;
            }
        }
    }
    let mut wh = vec![0.0f32; o * r * kw];
    for oi in 0..o {
        for rr in 0..r {
            let sq = s[rr].max(0.0).sqrt();
            for x in 0..kw {
                wh[(oi * r + rr) * kw + x] = v.data()[(oi * kw + x) * full + rr] * sq;
            }
        }
    }
    (
        Tensor::new(&[r, i, kh, 1], wv),
        Tensor::new(&[o, r, 1, kw], wh),
    )
}

/// Factor a Linear weight [O,F] at `rank` into ([R,F], [O,R]).
pub fn low_rank_linear_factors(weight: &Tensor, rank: usize) -> (Tensor, Tensor) {
    let (o, f) = (weight.dim(0), weight.dim(1));
    let (u, s, v) = svd_thin(weight);
    let r = rank.min(s.len()).max(1);
    let full = s.len();
    let mut w1 = vec![0.0f32; r * f];
    let mut w2 = vec![0.0f32; o * r];
    for rr in 0..r {
        let sq = s[rr].max(0.0).sqrt();
        for fi in 0..f {
            w1[rr * f + fi] = v.data()[fi * full + rr] * sq;
        }
        for oi in 0..o {
            w2[oi * r + rr] = u.data()[oi * full + rr] * sq;
        }
    }
    (Tensor::new(&[r, f], w1), Tensor::new(&[o, r], w2))
}

/// What an SVD application did to one layer.
#[derive(Debug, Clone)]
pub struct SvdReport {
    pub rank: usize,
    pub full_rank: usize,
}

/// Factor node `name` in place at compression `ratio`. Conv2d becomes a
/// spatial-SVD pair `{name}.svd_v` + `{name}.svd_h`; Linear becomes a
/// low-rank pair `{name}.svd_in` + `{name}.svd_out`. Returns `None` for
/// ineligible nodes (depthwise, activations, missing).
pub fn svd_apply(
    g: &mut Graph,
    name: &str,
    ratio: f32,
    input_shape: &[usize],
) -> Option<SvdReport> {
    svd_apply_impl(g, name, ratio, input_shape, true)
}

/// Shape-only variant for MAC accounting: the factor tensors are zeros of
/// the correct dimensions, skipping the Jacobi SVD entirely. The resulting
/// graph has exactly the MAC count of the real factorization.
pub(crate) fn svd_apply_structural(
    g: &mut Graph,
    name: &str,
    ratio: f32,
    input_shape: &[usize],
) -> Option<SvdReport> {
    svd_apply_impl(g, name, ratio, input_shape, false)
}

fn svd_apply_impl(
    g: &mut Graph,
    name: &str,
    ratio: f32,
    input_shape: &[usize],
    with_values: bool,
) -> Option<SvdReport> {
    let idx = g.find(name)?;
    // Copy the layer out first — the surgery below needs `&mut g`.
    enum Layer {
        Conv(Tensor, Vec<f32>, Conv2dSpec),
        Lin(Tensor, Vec<f32>),
    }
    let layer = match &g.nodes[idx].op {
        Op::Conv2d { weight, bias, spec } => Layer::Conv(weight.clone(), bias.clone(), *spec),
        Op::Linear { weight, bias } => Layer::Lin(weight.clone(), bias.clone()),
        _ => return None,
    };
    match layer {
        Layer::Conv(weight, bias, spec) => {
            let (o, i, kh, kw) = (weight.dim(0), weight.dim(1), weight.dim(2), weight.dim(3));
            let shapes = g.infer_shapes(input_shape);
            let mid_w = match g.nodes[idx].inputs[0] {
                Input::Graph => input_shape[3],
                Input::Node(j) => shapes[j][3],
            };
            let out_w = shapes[idx][3];
            let rank = spatial_svd_rank(o, i, kh, kw, mid_w, out_w, ratio);
            let full = (i * kh).min(o * kw);
            let (wv, wh) = if with_values {
                spatial_svd_factors(&weight, rank)
            } else {
                (
                    Tensor::zeros(&[rank, i, kh, 1]),
                    Tensor::zeros(&[o, rank, 1, kw]),
                )
            };
            let rank = wv.dim(0);
            let spec_v = Conv2dSpec::asym(spec.stride_h, 1, spec.pad_h, 0);
            let spec_h = Conv2dSpec::asym(1, spec.stride_w, 0, spec.pad_w);
            g.replace_with_sequence(
                idx,
                vec![
                    (
                        format!("{name}.svd_v"),
                        Op::Conv2d {
                            weight: wv,
                            bias: vec![0.0; rank],
                            spec: spec_v,
                        },
                    ),
                    (
                        format!("{name}.svd_h"),
                        Op::Conv2d {
                            weight: wh,
                            bias,
                            spec: spec_h,
                        },
                    ),
                ],
            );
            Some(SvdReport {
                rank,
                full_rank: full,
            })
        }
        Layer::Lin(weight, bias) => {
            let (o, f) = (weight.dim(0), weight.dim(1));
            let rank = low_rank_linear_rank(o, f, ratio);
            let (w1, w2) = if with_values {
                low_rank_linear_factors(&weight, rank)
            } else {
                (Tensor::zeros(&[rank, f]), Tensor::zeros(&[o, rank]))
            };
            let rank = w1.dim(0);
            g.replace_with_sequence(
                idx,
                vec![
                    (
                        format!("{name}.svd_in"),
                        Op::Linear {
                            weight: w1,
                            bias: vec![0.0; rank],
                        },
                    ),
                    (
                        format!("{name}.svd_out"),
                        Op::Linear { weight: w2, bias },
                    ),
                ],
            );
            Some(SvdReport {
                rank,
                full_rank: o.min(f),
            })
        }
    }
}

/// Nodes eligible for [`svd_apply`], in topological order.
pub fn svd_candidates(g: &Graph) -> Vec<String> {
    g.nodes
        .iter()
        .filter(|n| matches!(n.op, Op::Conv2d { .. } | Op::Linear { .. }))
        .map(|n| n.name.clone())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn jacobi_svd_reconstructs() {
        let mut rng = Rng::new(1);
        for &(m, n) in &[(6usize, 4usize), (4, 6), (9, 9), (1, 5), (12, 3)] {
            let a = Tensor::randn(&mut rng, &[m, n], 1.0);
            let (u, s, v) = svd_thin(&a);
            let r = m.min(n);
            assert_eq!(u.shape(), &[m, r]);
            assert_eq!(v.shape(), &[n, r]);
            // Reconstruct and compare.
            let mut rec = vec![0.0f32; m * n];
            for i in 0..m {
                for j in 0..n {
                    let mut acc = 0.0f32;
                    for k in 0..r {
                        acc += u.data()[i * r + k] * s[k] * v.data()[j * r + k];
                    }
                    rec[i * n + j] = acc;
                }
            }
            let rec = Tensor::new(&[m, n], rec);
            assert!(a.max_abs_diff(&rec) < 1e-5, "({m},{n}): {}", a.max_abs_diff(&rec));
            // Descending singular values.
            for k in 1..r {
                assert!(s[k] <= s[k - 1] + 1e-6);
            }
        }
    }

    #[test]
    fn full_rank_factors_reproduce_conv_weight() {
        let mut rng = Rng::new(2);
        let w = Tensor::randn(&mut rng, &[4, 3, 3, 3], 0.5);
        let full = (3 * 3usize).min(4 * 3);
        let (wv, wh) = spatial_svd_factors(&w, full);
        // Compose: W'[o,i,y,x] = Σ_r wv[r,i,y,0]·wh[o,r,0,x].
        let r = wv.dim(0);
        let mut rec = Tensor::zeros(w.shape());
        let (o, i, kh, kw) = (4, 3, 3, 3);
        for oi in 0..o {
            for ii in 0..i {
                for y in 0..kh {
                    for x in 0..kw {
                        let mut acc = 0.0f32;
                        for rr in 0..r {
                            acc += wv.data()[(rr * i + ii) * kh + y]
                                * wh.data()[(oi * r + rr) * kw + x];
                        }
                        rec.data_mut()[((oi * i + ii) * kh + y) * kw + x] = acc;
                    }
                }
            }
        }
        assert!(w.max_abs_diff(&rec) < 1e-5, "{}", w.max_abs_diff(&rec));
    }

    #[test]
    fn rank_selection_monotone_in_ratio() {
        let mut last = 0usize;
        for ratio in [0.25f32, 0.5, 0.75, 1.0] {
            let r = spatial_svd_rank(16, 16, 3, 3, 8, 8, ratio);
            assert!(r >= last, "rank not monotone at {ratio}");
            last = r;
        }
        assert_eq!(spatial_svd_rank(16, 16, 3, 3, 8, 8, 1.0), 48);
        assert_eq!(low_rank_linear_rank(10, 64, 1.0), 10);
        assert!(low_rank_linear_rank(10, 64, 0.5) * (10 + 64) <= 320);
    }
}
