//! Synthetic dataset substrate (DESIGN.md §3: ImageNet / Cityscapes-like /
//! ADAS traces / LibriSpeech are data gates — we generate procedural
//! equivalents that exercise the same code paths and give the models a real
//! signal to learn, so quantization has real accuracy to destroy/recover).
//!
//! All generators are deterministic in (seed, index): batch `i` of a
//! dataset is identical across runs, processes, and the Rust/PJRT engines.

use crate::rng::Rng;
use crate::tensor::Tensor;
use crate::zoo;

/// Class-conditional procedural images ("SynthImageNet").
///
/// Each class has a fixed signature: a 2-D sinusoidal texture with
/// class-specific frequency/phase per RGB channel. A sample is its class
/// signature + brightness jitter + pixel noise. Linear classifiers cannot
/// solve it perfectly at the noise level we use, so accuracy responds
/// smoothly to quantization noise — like real vision tasks.
pub struct SynthImageNet {
    pub classes: usize,
    seed: u64,
    /// Per class, per channel: (fx, fy, phase, amp).
    sigs: Vec<[(f32, f32, f32, f32); 3]>,
    pub noise: f32,
}

impl SynthImageNet {
    pub fn new(seed: u64) -> SynthImageNet {
        let classes = zoo::CLS_CLASSES;
        let mut rng = Rng::new(seed ^ 0x5117_1e7);
        let sigs = (0..classes)
            .map(|_| {
                [0, 1, 2].map(|_| {
                    (
                        rng.uniform_in(0.5, 3.5),
                        rng.uniform_in(0.5, 3.5),
                        rng.uniform_in(0.0, std::f32::consts::TAU),
                        rng.uniform_in(0.35, 0.7),
                    )
                })
            })
            .collect();
        SynthImageNet {
            classes,
            seed,
            sigs,
            // High enough that the task is not linearly saturable: trained
            // accuracy sits in the ~85-95% band, leaving quantization a
            // measurable margin to destroy (and PTQ/QAT to recover).
            noise: 0.85,
        }
    }

    /// Deterministic batch `index` of size `n`: (images [N,3,32,32] in
    /// roughly [-1, 1.5], labels).
    pub fn batch(&self, index: u64, n: usize) -> (Tensor, Vec<usize>) {
        let mut rng = Rng::new(self.seed.wrapping_add(index.wrapping_mul(0x9e37)));
        let (h, w) = (32usize, 32usize);
        let mut data = vec![0.0f32; n * 3 * h * w];
        let mut labels = Vec::with_capacity(n);
        for ni in 0..n {
            let label = rng.below(self.classes);
            labels.push(label);
            let bright = rng.uniform_in(0.8, 1.2);
            for c in 0..3 {
                let (fx, fy, ph, amp) = self.sigs[label][c];
                let base = (ni * 3 + c) * h * w;
                for y in 0..h {
                    for x in 0..w {
                        let v = amp
                            * ((fx * x as f32 * std::f32::consts::TAU / w as f32
                                + fy * y as f32 * std::f32::consts::TAU / h as f32
                                + ph)
                                .sin());
                        data[base + y * w + x] =
                            bright * v + self.noise * rng.normal();
                    }
                }
            }
        }
        (Tensor::new(&[n, 3, h, w], data), labels)
    }
}

/// Procedural segmentation scenes ("SynthSeg"): background (class 0) plus
/// 1–3 axis-aligned rectangles of classes 1..SEG_CLASSES, each rendered
/// with a class-specific color and texture into the image. Per-pixel labels.
pub struct SynthSeg {
    seed: u64,
    pub classes: usize,
}

impl SynthSeg {
    pub fn new(seed: u64) -> SynthSeg {
        SynthSeg {
            seed,
            classes: zoo::SEG_CLASSES,
        }
    }

    /// (images [N,3,32,32], labels [N,32,32] row-major).
    pub fn batch(&self, index: u64, n: usize) -> (Tensor, Vec<usize>) {
        let mut rng = Rng::new(self.seed.wrapping_add(index.wrapping_mul(0x51ab)));
        let (h, w) = (32usize, 32usize);
        let mut data = vec![0.0f32; n * 3 * h * w];
        let mut labels = vec![0usize; n * h * w];
        for ni in 0..n {
            // Background texture.
            for c in 0..3 {
                let base = (ni * 3 + c) * h * w;
                for k in 0..h * w {
                    data[base + k] = 0.1 * rng.normal();
                }
            }
            let num_rects = 1 + rng.below(3);
            for _ in 0..num_rects {
                let class = 1 + rng.below(self.classes - 1);
                let rw = 6 + rng.below(14);
                let rh = 6 + rng.below(14);
                let x0 = rng.below(w - rw);
                let y0 = rng.below(h - rh);
                // Class-specific color: channel weights from class id.
                let col = [
                    ((class * 37) % 7) as f32 / 7.0 + 0.3,
                    ((class * 53) % 7) as f32 / 7.0 + 0.3,
                    ((class * 71) % 7) as f32 / 7.0 + 0.3,
                ];
                for y in y0..y0 + rh {
                    for x in x0..x0 + rw {
                        labels[ni * h * w + y * w + x] = class;
                        for c in 0..3 {
                            data[(ni * 3 + c) * h * w + y * w + x] =
                                col[c] + 0.15 * rng.normal();
                        }
                    }
                }
            }
        }
        (Tensor::new(&[n, 3, h, w], data), labels)
    }
}

/// Ground-truth object for SynthDet.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DetObject {
    /// Grid cell (row, col) containing the object center.
    pub cell: (usize, usize),
    pub class: usize,
    /// Center offset within the cell, in [0,1)².
    pub offset: (f32, f32),
    /// Width/height as a fraction of image size.
    pub size: (f32, f32),
}

/// ADAS-like detection scenes ("SynthDet"): 64×64 images with 1–3 colored
/// square "vehicles"; targets per 8×8 grid cell (objectness, class, box).
pub struct SynthDet {
    seed: u64,
    pub classes: usize,
}

impl SynthDet {
    pub fn new(seed: u64) -> SynthDet {
        SynthDet {
            seed,
            classes: zoo::DET_CLASSES,
        }
    }

    /// (images [N,3,64,64], per-image object lists).
    pub fn batch(&self, index: u64, n: usize) -> (Tensor, Vec<Vec<DetObject>>) {
        let mut rng = Rng::new(self.seed.wrapping_add(index.wrapping_mul(0xde7)));
        let (h, w) = (64usize, 64usize);
        let g = zoo::DET_GRID;
        let cell = w / g;
        let mut data = vec![0.0f32; n * 3 * h * w];
        let mut objects = Vec::with_capacity(n);
        for ni in 0..n {
            for c in 0..3 {
                let base = (ni * 3 + c) * h * w;
                for k in 0..h * w {
                    data[base + k] = 0.1 * rng.normal();
                }
            }
            let count = 1 + rng.below(3);
            let mut objs: Vec<DetObject> = Vec::new();
            for _ in 0..count {
                let class = rng.below(self.classes);
                let size_px = 8 + rng.below(10);
                let cx = size_px / 2 + rng.below(w - size_px);
                let cy = size_px / 2 + rng.below(h - size_px);
                let cell_rc = (cy / cell, cx / cell);
                if objs.iter().any(|o| o.cell == cell_rc) {
                    continue; // one object per cell (YOLO-v1 style)
                }
                let col = [
                    ((class * 41) % 5) as f32 / 5.0 + 0.4,
                    ((class * 59) % 5) as f32 / 5.0 + 0.4,
                    ((class * 83) % 5) as f32 / 5.0 + 0.4,
                ];
                let (x0, y0) = (cx - size_px / 2, cy - size_px / 2);
                for y in y0..(y0 + size_px).min(h) {
                    for x in x0..(x0 + size_px).min(w) {
                        for c in 0..3 {
                            data[(ni * 3 + c) * h * w + y * w + x] =
                                col[c] + 0.12 * rng.normal();
                        }
                    }
                }
                objs.push(DetObject {
                    cell: cell_rc,
                    class,
                    offset: (
                        (cy % cell) as f32 / cell as f32,
                        (cx % cell) as f32 / cell as f32,
                    ),
                    size: (size_px as f32 / h as f32, size_px as f32 / w as f32),
                });
            }
            objects.push(objs);
        }
        (Tensor::new(&[n, 3, h, w], data), objects)
    }
}

/// Token-sequence "speech" ("SynthSpeech"): each frame carries one of
/// `SPEECH_TOKENS` tokens rendered as a token-specific feature pattern, with
/// temporal smearing between adjacent frames (the reason bi-directional
/// context helps). Per-frame token labels; the metric is token error rate.
pub struct SynthSpeech {
    seed: u64,
    pub tokens: usize,
    /// Per token: feature signature [F].
    sigs: Vec<Vec<f32>>,
}

impl SynthSpeech {
    pub fn new(seed: u64) -> SynthSpeech {
        let tokens = zoo::SPEECH_TOKENS;
        let f = zoo::SPEECH_FEATS;
        let mut rng = Rng::new(seed ^ 0x57ee_c4);
        let sigs = (0..tokens)
            .map(|_| rng.normal_vec(f, 1.0))
            .collect();
        SynthSpeech {
            seed,
            tokens,
            sigs,
        }
    }

    /// (sequences [N,T,F], labels [N,T] row-major).
    pub fn batch(&self, index: u64, n: usize) -> (Tensor, Vec<usize>) {
        let mut rng = Rng::new(self.seed.wrapping_add(index.wrapping_mul(0xabcd)));
        let (t, f) = (zoo::SPEECH_T, zoo::SPEECH_FEATS);
        let mut data = vec![0.0f32; n * t * f];
        let mut labels = vec![0usize; n * t];
        for ni in 0..n {
            // Random token run-lengths (tokens persist 2-5 frames).
            let mut ti = 0usize;
            while ti < t {
                let tok = rng.below(self.tokens);
                let run = 2 + rng.below(4);
                for _ in 0..run {
                    if ti >= t {
                        break;
                    }
                    labels[ni * t + ti] = tok;
                    ti += 1;
                }
            }
            // Render: signature + smear from neighbours + noise.
            for ti in 0..t {
                let tok = labels[ni * t + ti];
                let prev = if ti > 0 { labels[ni * t + ti - 1] } else { tok };
                let next = if ti + 1 < t {
                    labels[ni * t + ti + 1]
                } else {
                    tok
                };
                for fi in 0..f {
                    // Noise level tuned so trained FP32 TER sits in the
                    // ~5-15% band (Table 5.2's regime), not at zero.
                    data[(ni * t + ti) * f + fi] = 0.55 * self.sigs[tok][fi]
                        + 0.225 * self.sigs[prev][fi]
                        + 0.225 * self.sigs[next][fi]
                        + 0.9 * rng.normal();
                }
            }
        }
        (Tensor::new(&[n, t, f], data), labels)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn imagenet_batches_deterministic() {
        let d = SynthImageNet::new(1);
        let (x1, y1) = d.batch(5, 4);
        let (x2, y2) = d.batch(5, 4);
        assert_eq!(x1, x2);
        assert_eq!(y1, y2);
        let (x3, _) = d.batch(6, 4);
        assert_ne!(x1, x3);
    }

    #[test]
    fn imagenet_labels_in_range_and_varied() {
        let d = SynthImageNet::new(2);
        let (_, labels) = d.batch(0, 128);
        assert!(labels.iter().all(|&l| l < d.classes));
        let distinct: std::collections::HashSet<_> = labels.iter().collect();
        assert!(distinct.len() >= 8);
    }

    #[test]
    fn imagenet_classes_are_separable_by_signature() {
        // Same-class images should correlate more than cross-class ones.
        let d = SynthImageNet::new(3);
        let (x, y) = d.batch(0, 64);
        let img = |i: usize| &x.data()[i * 3 * 1024..(i + 1) * 3 * 1024];
        let dot = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(u, v)| u * v).sum::<f32>() / a.len() as f32
        };
        let mut same = Vec::new();
        let mut diff = Vec::new();
        for i in 0..64 {
            for j in (i + 1)..64 {
                let c = dot(img(i), img(j));
                if y[i] == y[j] {
                    same.push(c);
                } else {
                    diff.push(c);
                }
            }
        }
        let mean = |v: &[f32]| v.iter().sum::<f32>() / v.len().max(1) as f32;
        assert!(mean(&same) > mean(&diff) + 0.05, "{} vs {}", mean(&same), mean(&diff));
    }

    #[test]
    fn seg_labels_match_shapes() {
        let d = SynthSeg::new(4);
        let (x, labels) = d.batch(0, 2);
        assert_eq!(x.shape(), &[2, 3, 32, 32]);
        assert_eq!(labels.len(), 2 * 32 * 32);
        assert!(labels.iter().all(|&l| l < d.classes));
        // Non-trivial foreground.
        let fg = labels.iter().filter(|&&l| l > 0).count();
        assert!(fg > 50, "fg={fg}");
    }

    #[test]
    fn det_objects_well_formed() {
        let d = SynthDet::new(5);
        let (x, objs) = d.batch(0, 8);
        assert_eq!(x.shape(), &[8, 3, 64, 64]);
        for img_objs in &objs {
            assert!(!img_objs.is_empty());
            for o in img_objs {
                assert!(o.cell.0 < 8 && o.cell.1 < 8);
                assert!(o.class < d.classes);
                assert!(o.offset.0 >= 0.0 && o.offset.0 < 1.0);
            }
            // One object per cell.
            let mut cells: Vec<_> = img_objs.iter().map(|o| o.cell).collect();
            cells.sort();
            cells.dedup();
            assert_eq!(cells.len(), img_objs.len());
        }
    }

    #[test]
    fn speech_sequences_deterministic_and_labeled() {
        let d = SynthSpeech::new(6);
        let (x, y) = d.batch(3, 4);
        assert_eq!(x.shape(), &[4, zoo::SPEECH_T, zoo::SPEECH_FEATS]);
        assert_eq!(y.len(), 4 * zoo::SPEECH_T);
        let (x2, y2) = d.batch(3, 4);
        assert_eq!(x, x2);
        assert_eq!(y, y2);
        assert!(y.iter().all(|&l| l < d.tokens));
    }

    #[test]
    fn speech_tokens_form_runs() {
        let d = SynthSpeech::new(7);
        let (_, y) = d.batch(0, 16);
        // Adjacent-frame agreement should be well above chance (1/6).
        let t = zoo::SPEECH_T;
        let mut agree = 0usize;
        let mut total = 0usize;
        for ni in 0..16 {
            for ti in 1..t {
                total += 1;
                if y[ni * t + ti] == y[ni * t + ti - 1] {
                    agree += 1;
                }
            }
        }
        assert!(agree as f32 / total as f32 > 0.5);
    }
}
