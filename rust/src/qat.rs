//! Quantization-aware training (paper chapter 5).
//!
//! QAT models quantization noise *during* training: the forward pass runs
//! through the simulation quantizers (fig 5.1 top) and the backward pass
//! treats each quantizer as identity — the straight-through estimator
//! (STE, Bengio et al. 2013) — so gradients flow to the underlying FP32
//! shadow weights (fig 5.1 bottom).
//!
//! The implementation follows the recommended fig 5.2 pipeline:
//! PTQ-initialized sim (CLE + range setting) → static BN folding (§5.2.1;
//! folding happened when the sim was built) → STE fine-tuning with
//! periodic range updates → export.
//!
//! Two engines run the same math:
//! * the pure-Rust trainer here ([`fit_qat`] / [`fit_fp32`]), built on
//!   [`crate::graph::backward`];
//! * the PJRT artifacts (`*_fp32_step` / `*_qat_step`) lowered from the
//!   JAX L2 models, driven by [`crate::runtime`] — the cross-engine tests
//!   check they agree.

use crate::graph::{backward, backward_train, Graph};
use crate::quantsim::QuantizationSimModel;
use crate::task::{loss_and_grad, TaskData};
use crate::tensor::Tensor;

/// Trainer configuration (paper §5.2 usage note: 10–20% of original
/// epochs, LR comparable to the FP32 model's final LR, divide by 10 every
/// few epochs).
#[derive(Debug, Clone, Copy)]
pub struct TrainConfig {
    pub steps: usize,
    pub batch_size: usize,
    pub lr: f32,
    pub momentum: f32,
    /// Divide LR by `lr_decay` every `lr_decay_every` steps (0 = constant).
    pub lr_decay_every: usize,
    pub lr_decay: f32,
    /// Record a loss point every `log_every` steps.
    pub log_every: usize,
    /// QAT: re-run range setting every N steps (0 = freeze initial ranges).
    /// This is the "quantization ranges … updated at each iteration"
    /// min-max variant of §5.1 at configurable granularity.
    pub recalibrate_every: usize,
    /// Calibration batches used per recalibration.
    pub calib_batches: usize,
    /// Global L2 gradient-norm clip (0 = off). Keeps the hotter detector
    /// LRs stable across seeds.
    pub clip_norm: f32,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            steps: 300,
            batch_size: 16,
            lr: 0.05,
            momentum: 0.9,
            lr_decay_every: 120,
            lr_decay: 10.0,
            log_every: 20,
            recalibrate_every: 50,
            calib_batches: 2,
            clip_norm: 5.0,
        }
    }
}

/// One logged training point.
#[derive(Debug, Clone, Copy)]
pub struct TrainPoint {
    pub step: usize,
    pub loss: f32,
    pub lr: f32,
}

/// Loss curve of one run.
#[derive(Debug, Clone, Default)]
pub struct TrainLog {
    pub points: Vec<TrainPoint>,
}

impl TrainLog {
    pub fn final_loss(&self) -> f32 {
        self.points.last().map(|p| p.loss).unwrap_or(f32::NAN)
    }

    /// Mean loss of the first / last `k` logged points — a robust
    /// "did it learn" signal for tests and reports.
    pub fn head_tail_mean(&self, k: usize) -> (f32, f32) {
        let n = self.points.len();
        let k = k.min(n).max(1);
        let head = self.points[..k].iter().map(|p| p.loss).sum::<f32>() / k as f32;
        let tail = self.points[n - k..].iter().map(|p| p.loss).sum::<f32>() / k as f32;
        (head, tail)
    }

    pub fn render(&self) -> String {
        self.points
            .iter()
            .map(|p| format!("step {:>5}  loss {:.4}  lr {:.2e}", p.step, p.loss, p.lr))
            .collect::<Vec<_>>()
            .join("\n")
    }
}

/// SGD-with-momentum state per node.
#[derive(Default, Clone)]
struct Momentum {
    weight: Option<Vec<f32>>,
    weight2: Option<Vec<f32>>,
    bias: Option<Vec<f32>>,
    gamma: Option<Vec<f32>>,
    beta: Option<Vec<f32>>,
}

fn sgd_update(buf: &mut Option<Vec<f32>>, grad: &[f32], param: &mut [f32], lr: f32, mu: f32) {
    let b = buf.get_or_insert_with(|| vec![0.0; grad.len()]);
    for ((bv, &gv), pv) in b.iter_mut().zip(grad).zip(param.iter_mut()) {
        *bv = mu * *bv + gv;
        *pv -= lr * *bv;
    }
}

/// Global L2 norm of all parameter gradients.
fn grad_norm(grads: &crate::graph::GraphGrads) -> f32 {
    let mut sq = 0.0f64;
    for ng in &grads.nodes {
        for t in [&ng.weight, &ng.weight2] {
            if let Some(t) = t {
                sq += t.data().iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>();
            }
        }
        for v in [&ng.bias, &ng.gamma, &ng.beta] {
            if let Some(v) = v {
                sq += v.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>();
            }
        }
    }
    (sq as f32).sqrt()
}

fn apply_grads(
    g: &mut Graph,
    grads: &crate::graph::GraphGrads,
    momenta: &mut [Momentum],
    lr: f32,
    mu: f32,
    clip_norm: f32,
) {
    // Global-norm gradient clipping (scales LR rather than copying grads).
    let mut lr = lr;
    if clip_norm > 0.0 {
        let norm = grad_norm(grads);
        if norm > clip_norm {
            lr *= clip_norm / norm;
        }
    }
    for (idx, ng) in grads.nodes.iter().enumerate() {
        let m = &mut momenta[idx];
        let op = &mut g.nodes[idx].op;
        if let (Some(dw), Some(w)) = (&ng.weight, op.weight_mut()) {
            sgd_update(&mut m.weight, dw.data(), w.data_mut(), lr, mu);
        }
        if let Some(dw2) = &ng.weight2 {
            if let crate::graph::Op::Lstm { w_hh, .. } = op {
                sgd_update(&mut m.weight2, dw2.data(), w_hh.data_mut(), lr, mu);
            }
        }
        if let (Some(db), Some(b)) = (&ng.bias, op.bias_mut()) {
            sgd_update(&mut m.bias, db, b, lr, mu);
        }
        if let crate::graph::Op::BatchNorm { gamma, beta, .. } = op {
            if let Some(dg) = &ng.gamma {
                sgd_update(&mut m.gamma, dg, gamma, lr, mu);
            }
            if let Some(dbta) = &ng.beta {
                sgd_update(&mut m.beta, dbta, beta, lr, mu);
            }
        }
    }
}

fn lr_at(cfg: &TrainConfig, step: usize) -> f32 {
    // Linear warmup over the first 5% of steps, then step decay.
    let warmup = (cfg.steps / 20).max(1);
    let base = if cfg.lr_decay_every == 0 {
        cfg.lr
    } else {
        cfg.lr / cfg.lr_decay.powi((step / cfg.lr_decay_every) as i32)
    };
    if step < warmup {
        base * (step + 1) as f32 / warmup as f32
    } else {
        base
    }
}

/// Train an FP32 graph in place. This is the "pretrained FP32 model"
/// producer every paper pipeline starts from.
pub fn fit_fp32(g: &mut Graph, model: &str, data: &TaskData, cfg: &TrainConfig) -> TrainLog {
    let mut momenta = vec![Momentum::default(); g.nodes.len()];
    let mut log = TrainLog::default();
    let no_overrides: Vec<Option<Tensor>> = vec![None; g.nodes.len()];
    for step in 0..cfg.steps {
        let (x, targets) = data.batch(step as u64, cfg.batch_size);
        // Training-mode BN: batch statistics + running-stat updates.
        let (acts, bn_stats) = g.forward_train(&x, 0.9);
        // Targets come from this model's own TaskData, so a mismatch is a
        // caller bug, not a user input — fail loudly with the diagnostic.
        let (loss, d_out) =
            loss_and_grad(model, &acts[g.output], &targets).expect("fit_fp32 model/data pair");
        let grads = backward_train(g, &x, &acts, &d_out, &no_overrides, &bn_stats);
        apply_grads(g, &grads, &mut momenta, lr_at(cfg, step), cfg.momentum, cfg.clip_norm);
        if step % cfg.log_every.max(1) == 0 || step + 1 == cfg.steps {
            log.points.push(TrainPoint {
                step,
                loss,
                lr: lr_at(cfg, step),
            });
        }
    }
    log
}

/// Quantization-aware fine-tuning of a PTQ-initialized sim, in place
/// (code block 5.1's `trainer_function(model=sim.model, …)`).
///
/// STE: the forward uses the qdq'd weights/activations; the backward
/// receives those same qdq'd weights as `weight_overrides` and skips the
/// quantizer blocks, so the computed gradient is exactly fig 5.1's.
/// Updates land on the FP32 shadow weights inside `sim.graph`.
pub fn fit_qat(
    sim: &mut QuantizationSimModel,
    model: &str,
    data: &TaskData,
    cfg: &TrainConfig,
) -> TrainLog {
    let mut momenta = vec![Momentum::default(); sim.graph.nodes.len()];
    let mut log = TrainLog::default();
    for step in 0..cfg.steps {
        if cfg.recalibrate_every > 0 && step % cfg.recalibrate_every == 0 && step > 0 {
            // Range update (§5.1): weights moved, so re-set encodings.
            // Frozen (AdaRound) parameter encodings survive.
            let calib = data.calibration(cfg.calib_batches, cfg.batch_size);
            sim.compute_encodings(&calib);
        }
        let (x, targets) = data.batch(step as u64, cfg.batch_size);
        let (acts, captured) = sim.forward_capturing(&x);
        let (loss, d_out) = loss_and_grad(model, &acts[sim.graph.output], &targets)
            .expect("fit_qat model/data pair");
        let grads = backward(&sim.graph, &x, &acts, &d_out, &captured);
        apply_grads(
            &mut sim.graph,
            &grads,
            &mut momenta,
            lr_at(cfg, step),
            cfg.momentum,
            cfg.clip_norm,
        );
        // The shadow weights moved: the next forward must re-quantize.
        sim.invalidate_weight_cache();
        if step % cfg.log_every.max(1) == 0 || step + 1 == cfg.steps {
            log.points.push(TrainPoint {
                step,
                loss,
                lr: lr_at(cfg, step),
            });
        }
    }
    // Final range refresh so exported encodings match the trained weights.
    let calib = data.calibration(cfg.calib_batches, cfg.batch_size);
    sim.compute_encodings(&calib);
    log
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quantsim::{QuantParams, QuantizationSimModel};
    use crate::task::TaskData;
    use crate::zoo;

    fn quick_cfg(steps: usize) -> TrainConfig {
        TrainConfig {
            steps,
            batch_size: 8,
            lr: 0.05,
            lr_decay_every: 0,
            log_every: 5,
            recalibrate_every: 20,
            calib_batches: 1,
            ..Default::default()
        }
    }

    #[test]
    fn fp32_training_reduces_loss() {
        let mut g = zoo::build("mobimini", 80).unwrap();
        let data = TaskData::new("mobimini", 81).unwrap();
        let log = fit_fp32(&mut g, "mobimini", &data, &quick_cfg(120));
        let (head, tail) = log.head_tail_mean(3);
        assert!(tail < 0.9 * head, "loss did not fall: {head} -> {tail}");
    }

    #[test]
    fn qat_training_reduces_loss_through_quantizers() {
        let mut g = zoo::build("mobimini", 82).unwrap();
        let data = TaskData::new("mobimini", 83).unwrap();
        // Short FP32 warmup so quantization has signal to preserve.
        fit_fp32(&mut g, "mobimini", &data, &quick_cfg(40));
        let mut sim = QuantizationSimModel::with_defaults(g, QuantParams::default());
        sim.compute_encodings(&data.calibration(2, 8));
        let log = fit_qat(&mut sim, "mobimini", &data, &quick_cfg(60));
        let (head, tail) = log.head_tail_mean(3);
        assert!(tail < head, "QAT loss did not fall: {head} -> {tail}");
    }

    #[test]
    fn qat_trains_recurrent_models() {
        // Table 5.2's substrate: bi-LSTM QAT must be trainable.
        let mut g = zoo::build("speechmini", 84).unwrap();
        let data = TaskData::new("speechmini", 85).unwrap();
        fit_fp32(&mut g, "speechmini", &data, &quick_cfg(30));
        let mut sim = QuantizationSimModel::with_defaults(g, QuantParams::default());
        sim.compute_encodings(&data.calibration(1, 8));
        let log = fit_qat(&mut sim, "speechmini", &data, &quick_cfg(30));
        let (head, tail) = log.head_tail_mean(2);
        assert!(tail <= head * 1.05, "LSTM QAT diverged: {head} -> {tail}");
    }

    #[test]
    fn lr_schedule_divides() {
        let cfg = TrainConfig {
            steps: 40, // warmup = max(40/20, 1) = 2 steps
            lr: 1.0,
            lr_decay_every: 10,
            lr_decay: 10.0,
            ..Default::default()
        };
        // Linear warmup over the first steps/20 steps…
        assert!((lr_at(&cfg, 0) - 0.5).abs() < 1e-9);
        // …then the step-decay schedule.
        assert_eq!(lr_at(&cfg, 5), 1.0);
        assert!((lr_at(&cfg, 10) - 0.1).abs() < 1e-9);
        assert!((lr_at(&cfg, 25) - 0.01).abs() < 1e-9);
    }

    #[test]
    fn qat_updates_shadow_weights_not_quantized_copies() {
        let mut g = zoo::build("resmini", 86).unwrap();
        let data = TaskData::new("resmini", 87).unwrap();
        fit_fp32(&mut g, "resmini", &data, &quick_cfg(10));
        let mut sim = QuantizationSimModel::with_defaults(g, QuantParams::default());
        sim.compute_encodings(&data.calibration(1, 8));
        let idx = sim.graph.find("stem.conv").unwrap();
        let before = sim.graph.nodes[idx].op.weight().unwrap().clone();
        fit_qat(&mut sim, "resmini", &data, &quick_cfg(5));
        let after = sim.graph.nodes[idx].op.weight().unwrap();
        assert!(after.max_abs_diff(&before) > 0.0, "weights must move");
        // Shadow weights are FP32 (off-grid): qdq must still perturb them.
        let q = sim.quantized_weight(idx).unwrap();
        assert!(q.max_abs_diff(after) > 0.0);
    }
}
