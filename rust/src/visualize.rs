//! Weight-range visualization (paper §4.3, figs 4.2/4.3): per-channel
//! min/max "boxplots" rendered as ASCII for the terminal plus CSV export
//! for external plotting. AIMET ships this as its visualization API; the
//! debug flow (§4.8 "Visualizing layers") leans on it.

use crate::graph::Graph;
use crate::tensor::Tensor;

/// Per-channel range summary of a weight tensor (channel axis 0).
#[derive(Debug, Clone)]
pub struct ChannelRanges {
    pub layer: String,
    pub ranges: Vec<(f32, f32)>,
}

impl ChannelRanges {
    pub fn of(layer: &str, w: &Tensor) -> ChannelRanges {
        ChannelRanges {
            layer: layer.to_string(),
            ranges: w.channel_min_max(0),
        }
    }

    /// Spread statistic the CLE experiments report: max over channels of
    /// |range| divided by min over channels (∞-safe).
    pub fn spread(&self) -> f32 {
        let amax = |&(lo, hi): &(f32, f32)| hi.max(-lo).max(1e-12);
        let hi = self.ranges.iter().map(amax).fold(0.0f32, f32::max);
        let lo = self.ranges.iter().map(amax).fold(f32::INFINITY, f32::min);
        hi / lo
    }

    /// CSV rows: `channel,min,max`.
    pub fn to_csv(&self) -> String {
        let mut out = String::from("channel,min,max\n");
        for (i, (lo, hi)) in self.ranges.iter().enumerate() {
            out.push_str(&format!("{i},{lo},{hi}\n"));
        }
        out
    }

    /// ASCII boxplot: one row per channel, bar spanning [min, max] over the
    /// global range (the fig 4.2/4.3 visual).
    pub fn to_ascii(&self, width: usize) -> String {
        let gmin = self
            .ranges
            .iter()
            .map(|r| r.0)
            .fold(f32::INFINITY, f32::min);
        let gmax = self
            .ranges
            .iter()
            .map(|r| r.1)
            .fold(f32::NEG_INFINITY, f32::max);
        let span = (gmax - gmin).max(1e-12);
        let mut out = format!(
            "{} — per-channel weight ranges [{:.4}, {:.4}] (spread {:.1}×)\n",
            self.layer,
            gmin,
            gmax,
            self.spread()
        );
        for (i, (lo, hi)) in self.ranges.iter().enumerate() {
            let a = (((lo - gmin) / span) * (width - 1) as f32).round() as usize;
            let b = (((hi - gmin) / span) * (width - 1) as f32).round() as usize;
            let mut row: Vec<char> = vec![' '; width];
            let zero = (((0.0 - gmin) / span) * (width - 1) as f32).round() as usize;
            if zero < width {
                row[zero] = '|';
            }
            for cell in row.iter_mut().take(b.min(width - 1) + 1).skip(a) {
                *cell = '█';
            }
            out.push_str(&format!("ch{i:>3} {}\n", row.into_iter().collect::<String>()));
        }
        out
    }
}

/// Collect per-channel ranges of every weighted layer in a graph.
pub fn weight_ranges(g: &Graph) -> Vec<ChannelRanges> {
    g.nodes
        .iter()
        .filter_map(|n| n.op.weight().map(|w| ChannelRanges::of(&n.name, w)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spread_and_csv() {
        let w = Tensor::new(&[2, 1, 1, 2], vec![-1.0, 1.0, -0.1, 0.1]);
        let cr = ChannelRanges::of("dw", &w);
        assert!((cr.spread() - 10.0).abs() < 1e-4);
        let csv = cr.to_csv();
        assert!(csv.starts_with("channel,min,max\n"));
        assert_eq!(csv.lines().count(), 3);
    }

    #[test]
    fn ascii_renders_rows() {
        let w = Tensor::new(&[3, 1, 1, 2], vec![-2.0, 2.0, -0.5, 0.5, -1.0, 0.2]);
        let art = ChannelRanges::of("layer", &w).to_ascii(40);
        assert_eq!(art.lines().count(), 4); // header + 3 channels
        assert!(art.contains('█'));
    }

    #[test]
    fn graph_ranges_cover_weighted_layers() {
        let g = crate::zoo::build("mobimini", 1).unwrap();
        let ranges = weight_ranges(&g);
        // 1 stem + 3 dw + 3 pw + 1 fc = 8 weighted layers.
        assert_eq!(ranges.len(), 8);
    }
}
