//! `aimet` CLI entrypoint — see [`aimet::coordinator`] for the command
//! surface.
fn main() {
    aimet::coordinator::cli_main();
}
