#!/usr/bin/env bash
# One-stop CI gate: lint hygiene (fmt + clippy), tier-1 correctness
# (build + tests), then the perf/compression/engine bench gates.
# Runnable from any cwd:
#
#   scripts/ci.sh
#
# Exit code is nonzero on the first failing stage. Lints run FIRST so a
# kernel refactor cannot land with silent formatting or clippy drift —
# the hot-path modules lean on unsafe disjoint-write patterns where
# sloppy edits are expensive to review by eye.
set -euo pipefail

SCRIPT_DIR="$(cd "$(dirname "${BASH_SOURCE[0]}")" && pwd)"
cd "$SCRIPT_DIR/.."

echo "== ci: lint (cargo fmt --check && cargo clippy -- -D warnings) =="
(cd rust && cargo fmt --check)
(cd rust && cargo clippy --all-targets -- -D warnings)

echo "== ci: tier-1, native simd dispatch (cargo build --release && cargo test -q) =="
(cd rust && cargo build --release)
(cd rust && cargo test -q)

# The SIMD kernel tier must be a pure optimization: the whole suite —
# including the engine-vs-reference and sim-agreement properties — has to
# pass identically with dispatch pinned to the scalar reference kernels.
echo "== ci: tier-1, forced-scalar dispatch (AIMET_FORCE_SCALAR=1 cargo test -q) =="
(cd rust && AIMET_FORCE_SCALAR=1 cargo test -q)

# Weight bit-width must be a pure capacity choice: with every weighted
# layer forced to nibble-packed 4-bit (the W4A8 path), the kernel fuzz
# suite and the engine-vs-sim agreement properties must still hold — on
# the native SIMD tier (int4 unpack microkernels live) and again pinned
# to the scalar reference, so the nibble panels are proven bit-identical
# to the plain 4-bit grid on every dispatch path.
echo "== ci: W4A8, native dispatch (AIMET_FORCE_W4=1) =="
(cd rust && AIMET_FORCE_W4=1 cargo test -q --test engine_integration)
(cd rust && AIMET_FORCE_W4=1 cargo test -q --test simd_kernels)
echo "== ci: W4A8, forced-scalar dispatch (AIMET_FORCE_W4=1 AIMET_FORCE_SCALAR=1) =="
(cd rust && AIMET_FORCE_W4=1 AIMET_FORCE_SCALAR=1 cargo test -q --test engine_integration)
(cd rust && AIMET_FORCE_W4=1 AIMET_FORCE_SCALAR=1 cargo test -q --test simd_kernels)

# Thread count must be a pure scheduling choice: the wavefront executor and
# every parallel kernel are bit-identical at any pool width. Pin the engine
# suite to a deterministic single thread, then to a high thread count so
# cross-node fan-out (width > available fronts, nested GEMM splits) is
# actually exercised rather than left to the host's core count.
echo "== ci: engine suite, single-thread pool (AIMET_THREADS=1) =="
(cd rust && AIMET_THREADS=1 cargo test -q --test engine_integration)
(cd rust && AIMET_THREADS=1 cargo test -q --lib engine::)
echo "== ci: engine suite, wide pool (AIMET_THREADS=16) =="
(cd rust && AIMET_THREADS=16 cargo test -q --test engine_integration)
(cd rust && AIMET_THREADS=16 cargo test -q --lib engine::)

# Fault tolerance must hold at any pool width: the chaos suite (seeded
# panic/delay/overload storms against the batch server, exactly-one-reply
# + bit-identity + clean-drain invariants) runs natively, then pinned to
# a single worker thread where the batcher/client interleavings and the
# panic-recovery path are maximally adversarial.
echo "== ci: serve chaos suite (cargo test -q --test serve_chaos) =="
(cd rust && cargo test -q --test serve_chaos)
echo "== ci: serve chaos suite, single-thread pool (AIMET_THREADS=1) =="
(cd rust && AIMET_THREADS=1 cargo test -q --test serve_chaos)

# Observability must be a pure observer: the engine's agreement and
# serving properties have to pass with the span recorder + clip counters
# live on every forward (env-gated process-wide), and the observability
# suite itself must hold under recording pressure.
echo "== ci: engine suite, profiling enabled (AIMET_PROFILE=1) =="
(cd rust && AIMET_PROFILE=1 cargo test -q --test engine_integration)
(cd rust && AIMET_PROFILE=1 cargo test -q --test observability)

# Serving observability smoke: a short serve-bench run must emit a
# Prometheus exposition that passes the line-format validator and a drift
# CSV with the documented header. The shifted phase exercises the drift
# detector end to end; with only 8 requests most nodes grade low-data,
# which is fine — this stage validates the formats, the zoo-wide detector
# properties live in tests/observability.rs.
echo "== ci: serve-bench observability smoke (--metrics + --drift-report) =="
SMOKE_DIR="$(mktemp -d)"
trap 'rm -rf "$SMOKE_DIR"' EXIT
(cd rust && cargo run --release --quiet -- serve-bench --model mobimini \
    --clients 2 --requests 8 --drift-sample 1 --shift-inputs 4.0 \
    --metrics "$SMOKE_DIR/serve.prom" --drift-report "$SMOKE_DIR/drift.csv")
python3 "$SCRIPT_DIR/check_prom.py" "$SMOKE_DIR/serve.prom"
if ! head -1 "$SMOKE_DIR/drift.csv" | grep -q '^run,node,name,verdict'; then
    echo "ci: drift.csv header malformed: $(head -1 "$SMOKE_DIR/drift.csv")" >&2
    exit 1
fi
echo "== ci: observability smoke OK =="

echo "== ci: bench gates (scripts/bench_check.sh) =="
"$SCRIPT_DIR/bench_check.sh"

echo "== ci: all gates passed =="
