#!/usr/bin/env bash
# One-stop CI gate: tier-1 correctness (build + tests) followed by the
# perf/compression/engine bench gates. Runnable from any cwd:
#
#   scripts/ci.sh
#
# Exit code is nonzero on the first failing stage.
set -euo pipefail

SCRIPT_DIR="$(cd "$(dirname "${BASH_SOURCE[0]}")" && pwd)"
cd "$SCRIPT_DIR/.."

echo "== ci: tier-1 (cargo build --release && cargo test -q) =="
(cd rust && cargo build --release)
(cd rust && cargo test -q)

echo "== ci: bench gates (scripts/bench_check.sh) =="
"$SCRIPT_DIR/bench_check.sh"

echo "== ci: all gates passed =="
