#!/usr/bin/env bash
# Perf + compression + engine gate: build release, run the hotpath,
# compression and engine benches, and fail if
#   * BENCH_hotpath.json is missing or the quantsim/fp32 forward ratio
#     exceeds the paper-motivated 3.0x budget (rust/README.md §Perf), or
#   * BENCH_compress.json is missing, MAC reduction on the reference zoo
#     model falls below 40%, or the compression eval-score delta exceeds
#     2 points (rust/README.md §Compression), or
#   * BENCH_engine.json is missing, batched int8 engine throughput falls
#     below 1.5x the per-request fp32 forward, or engine batch-8 falls
#     below 2x batch-1 samples/sec (rust/README.md §Engine).
set -euo pipefail

# Resolve the repo root from the script's own location so the gate runs
# from any cwd (including via a symlink).
SCRIPT_DIR="$(cd "$(dirname "${BASH_SOURCE[0]}")" && pwd)"
cd "$SCRIPT_DIR/.."

(cd rust && cargo build --release)
(cd rust && cargo bench --bench hotpath)
(cd rust && cargo bench --bench compress)
(cd rust && cargo bench --bench engine)

for f in BENCH_hotpath.json BENCH_compress.json BENCH_engine.json; do
    if [[ ! -f "$f" ]]; then
        echo "bench_check: $f was not emitted" >&2
        exit 1
    fi
done

python3 - <<'EOF'
import json
import sys

def fmt(v, suffix="x"):
    """A missing metric renders as n/a instead of crashing the gate."""
    return f"{v:.1f}{suffix}" if isinstance(v, (int, float)) else "n/a"

with open("BENCH_hotpath.json") as f:
    d = json.load(f)

ratio = d["quantsim_over_fp32"]
if ratio > 3.0:
    sys.exit(f"bench_check: quantsim/fp32 forward ratio {ratio:.2f} > 3.0")

print(
    f"bench_check OK: quantsim/fp32 = {ratio:.2f}x (<= 3.0), "
    f"int-GEMM speedup vs naive = {fmt(d.get('int_gemm_speedup_vs_naive'))}"
)

with open("BENCH_compress.json") as f:
    c = json.load(f)

reduction = c["mac_reduction_pct"]
delta = c["eval_delta"]
if reduction < 40.0:
    sys.exit(f"bench_check: MAC reduction {reduction:.1f}% < 40%")
if abs(delta) > 2.0:
    sys.exit(f"bench_check: compression eval delta {delta:.2f} > 2 points")
print(
    f"bench_check OK: compression {reduction:.1f}% MAC reduction "
    f"(eval delta {delta:.2f} pts, int-GEMM forward speedup "
    f"{fmt(c.get('int_forward_speedup'))})"
)

with open("BENCH_engine.json") as f:
    e = json.load(f)

speedup = e["engine_batched_speedup_vs_fp32"]
scaling = e["engine_batch_scaling"]
if speedup < 1.5:
    sys.exit(
        f"bench_check: batched engine throughput {speedup:.2f}x fp32 forward < 1.5x"
    )
if scaling < 2.0:
    sys.exit(f"bench_check: engine batch-8/batch-1 scaling {scaling:.2f}x < 2.0x")
print(
    f"bench_check OK: engine batched {speedup:.2f}x fp32 (>= 1.5), "
    f"batch scaling {scaling:.2f}x (>= 2.0), "
    f"vs quantsim {fmt(e.get('engine_speedup_vs_quantsim_b8'))}, "
    f"max step deviation {fmt(e.get('max_step_deviation'), '')}"
)
EOF
