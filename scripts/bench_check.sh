#!/usr/bin/env bash
# Perf gate: build release, run the hotpath bench, and fail if the
# machine-readable baseline is missing or the quantsim/fp32 forward
# ratio exceeds the paper-motivated 3.0x budget (rust/README.md §Perf).
set -euo pipefail

cd "$(dirname "$0")/.."

(cd rust && cargo build --release)
(cd rust && cargo bench --bench hotpath)

if [[ ! -f BENCH_hotpath.json ]]; then
    echo "bench_check: BENCH_hotpath.json was not emitted" >&2
    exit 1
fi

python3 - <<'EOF'
import json
import sys

with open("BENCH_hotpath.json") as f:
    d = json.load(f)

ratio = d["quantsim_over_fp32"]
if ratio > 3.0:
    sys.exit(f"bench_check: quantsim/fp32 forward ratio {ratio:.2f} > 3.0")

speedup = d.get("int_gemm_speedup_vs_naive")
print(
    f"bench_check OK: quantsim/fp32 = {ratio:.2f}x (<= 3.0), "
    f"int-GEMM speedup vs naive = {speedup:.1f}x"
)
EOF
