#!/usr/bin/env bash
# Perf + compression gate: build release, run the hotpath and compression
# benches, and fail if
#   * BENCH_hotpath.json is missing or the quantsim/fp32 forward ratio
#     exceeds the paper-motivated 3.0x budget (rust/README.md §Perf), or
#   * BENCH_compress.json is missing, MAC reduction on the reference zoo
#     model falls below 40%, or the compression eval-score delta exceeds
#     2 points (rust/README.md §Compression).
set -euo pipefail

cd "$(dirname "$0")/.."

(cd rust && cargo build --release)
(cd rust && cargo bench --bench hotpath)
(cd rust && cargo bench --bench compress)

if [[ ! -f BENCH_hotpath.json ]]; then
    echo "bench_check: BENCH_hotpath.json was not emitted" >&2
    exit 1
fi
if [[ ! -f BENCH_compress.json ]]; then
    echo "bench_check: BENCH_compress.json was not emitted" >&2
    exit 1
fi

python3 - <<'EOF'
import json
import sys

with open("BENCH_hotpath.json") as f:
    d = json.load(f)

ratio = d["quantsim_over_fp32"]
if ratio > 3.0:
    sys.exit(f"bench_check: quantsim/fp32 forward ratio {ratio:.2f} > 3.0")

speedup = d.get("int_gemm_speedup_vs_naive")
print(
    f"bench_check OK: quantsim/fp32 = {ratio:.2f}x (<= 3.0), "
    f"int-GEMM speedup vs naive = {speedup:.1f}x"
)

with open("BENCH_compress.json") as f:
    c = json.load(f)

reduction = c["mac_reduction_pct"]
delta = c["eval_delta"]
if reduction < 40.0:
    sys.exit(f"bench_check: MAC reduction {reduction:.1f}% < 40%")
if abs(delta) > 2.0:
    sys.exit(f"bench_check: compression eval delta {delta:.2f} > 2 points")
print(
    f"bench_check OK: compression {reduction:.1f}% MAC reduction "
    f"(eval delta {delta:.2f} pts, int-GEMM forward speedup "
    f"{c['int_forward_speedup']:.2f}x)"
)
EOF
