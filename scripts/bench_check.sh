#!/usr/bin/env bash
# Perf + compression + engine gate: build release, run the hotpath,
# compression and engine benches, and fail if
#   * BENCH_hotpath.json is missing, the quantsim/fp32 forward ratio
#     exceeds the paper-motivated 3.0x budget, or the nibble-packed W4A8
#     GEMM falls below 1.3x the w8a8 path at 256^3 (rust/README.md
#     §Perf), or
#   * BENCH_compress.json is missing, MAC reduction on the reference zoo
#     model falls below 40%, or the compression eval-score delta exceeds
#     2 points (rust/README.md §Compression), or
#   * BENCH_engine.json is missing, batched int8 engine throughput falls
#     below 1.5x the per-request fp32 forward, engine batch-8 falls
#     below 2x batch-1 samples/sec, the packed engine performs ANY
#     steady-state heap allocation per forward (rust/README.md §Engine),
#     or the profiled-run overhead (span recorder + clip counters live)
#     exceeds 3% of the plain run (README.md §Observability), or the
#     robustness machinery (admission gate + deadline check + unwind
#     boundary, fault hooks off) costs more than 1% of the plain b8
#     forward (rust/README.md §Serving), or the AMP bit-width search
#     sheds less than 40% of the packed weight bytes or moves the task
#     score by more than 1 point (rust/README.md §Perf), or
#   * batch-8 engine throughput regresses below 0.9x the previous run
#     recorded in BENCH_history.jsonl (the perf ratchet; only applied when
#     the previous run used the same thread count AND the same SIMD
#     dispatch tier — see rust/README.md §Perf).
#
# On success, appends this run's headline numbers as one JSON line to
# BENCH_history.jsonl at the repo root (append-only trajectory; failed
# runs are never recorded).
set -euo pipefail

# Resolve the repo root from the script's own location so the gate runs
# from any cwd (including via a symlink).
SCRIPT_DIR="$(cd "$(dirname "${BASH_SOURCE[0]}")" && pwd)"
cd "$SCRIPT_DIR/.."

(cd rust && cargo build --release)
(cd rust && cargo bench --bench hotpath)
(cd rust && cargo bench --bench compress)
(cd rust && cargo bench --bench engine)

for f in BENCH_hotpath.json BENCH_compress.json BENCH_engine.json; do
    if [[ ! -f "$f" ]]; then
        echo "bench_check: $f was not emitted" >&2
        exit 1
    fi
done

python3 - <<'EOF'
import json
import sys

def fmt(v, suffix="x"):
    """A missing metric renders as n/a instead of crashing the gate."""
    return f"{v:.1f}{suffix}" if isinstance(v, (int, float)) else "n/a"

with open("BENCH_hotpath.json") as f:
    d = json.load(f)

ratio = d["quantsim_over_fp32"]
if ratio > 3.0:
    sys.exit(f"bench_check: quantsim/fp32 forward ratio {ratio:.2f} > 3.0")

print(
    f"bench_check OK: quantsim/fp32 = {ratio:.2f}x (<= 3.0), "
    f"int-GEMM speedup vs naive = {fmt(d.get('int_gemm_speedup_vs_naive'))}"
)

# W4A8 kernel gate: the nibble-packed int4 GEMM must beat the 8-bit
# container path by >= 1.3x at 256^3 (same harness, same grids) — the
# halved weight-panel bandwidth has to pay for the in-register unpack.
w8 = d.get("gemm_i8_256_gops")
w4 = d.get("gemm_w4a8_gops")
if not isinstance(w8, (int, float)) or not isinstance(w4, (int, float)):
    sys.exit("bench_check: BENCH_hotpath.json lacks gemm_i8_256_gops/gemm_w4a8_gops")
if w4 < 1.3 * w8:
    sys.exit(
        f"bench_check: w4a8 GEMM {w4:.2f} GOP/s < 1.3x the w8a8 path "
        f"({w8:.2f} GOP/s; floor {1.3 * w8:.2f})"
    )
print(
    f"bench_check OK: w4a8 GEMM {w4:.2f} GOP/s = {w4 / w8:.2f}x w8a8 (>= 1.3x) "
    f"[{d.get('simd_tier')}]"
)

with open("BENCH_compress.json") as f:
    c = json.load(f)

reduction = c["mac_reduction_pct"]
delta = c["eval_delta"]
if reduction < 40.0:
    sys.exit(f"bench_check: MAC reduction {reduction:.1f}% < 40%")
if abs(delta) > 2.0:
    sys.exit(f"bench_check: compression eval delta {delta:.2f} > 2 points")
print(
    f"bench_check OK: compression {reduction:.1f}% MAC reduction "
    f"(eval delta {delta:.2f} pts, int-GEMM forward speedup "
    f"{fmt(c.get('int_forward_speedup'))})"
)

with open("BENCH_engine.json") as f:
    e = json.load(f)

speedup = e["engine_batched_speedup_vs_fp32"]
scaling = e["engine_batch_scaling"]
if speedup < 1.5:
    sys.exit(
        f"bench_check: batched engine throughput {speedup:.2f}x fp32 forward < 1.5x"
    )
if scaling < 2.0:
    sys.exit(f"bench_check: engine batch-8/batch-1 scaling {scaling:.2f}x < 2.0x")

# AMP (greedy per-layer bit-width search) gate: on the reference model the
# search must shed >= 40% of the packed weight bytes while the task score
# moves by at most 1 point — the W4A8 deployment story in one number pair.
amp_red = e.get("amp_weight_reduction_pct")
amp_delta = e.get("amp_eval_delta")
if not isinstance(amp_red, (int, float)) or not isinstance(amp_delta, (int, float)):
    sys.exit("bench_check: BENCH_engine.json lacks amp_weight_reduction_pct/amp_eval_delta")
if amp_red < 40.0:
    sys.exit(f"bench_check: AMP packed-weight reduction {amp_red:.1f}% < 40%")
if abs(amp_delta) > 1.0:
    sys.exit(f"bench_check: AMP eval delta {amp_delta:+.2f} pts exceeds 1 point")
print(
    f"bench_check OK: AMP {amp_red:.1f}% packed-weight reduction "
    f"(eval delta {amp_delta:+.2f} pts, "
    f"{fmt(e.get('amp_low_bw_layers'), '')} layer(s) at 4b, "
    f"served weights {fmt(e.get('weight_bytes_mobimini'), ' B')})"
)

# Zero-allocation gate: the packed data path (arena plan + worker scratch)
# must not touch the heap in steady state. The bench counts through a
# wrapping GlobalAlloc; any nonzero value is a regression.
allocs = e.get("allocs_per_forward_b8")
if not isinstance(allocs, (int, float)):
    sys.exit("bench_check: BENCH_engine.json lacks allocs_per_forward_b8")
if allocs != 0:
    sys.exit(
        f"bench_check: {allocs:.2f} steady-state allocations per forward (must be 0)"
    )

# Observability overhead gate: a profiled b8 forward (spans + clip
# counters live) must stay within 3% of the plain run measured
# back-to-back in the same bench process. The bench also asserts the
# profiled forward is bit-identical; here we only gate the cost.
overhead = e.get("profile_overhead_pct")
if not isinstance(overhead, (int, float)):
    sys.exit("bench_check: BENCH_engine.json lacks profile_overhead_pct")
if overhead > 3.0:
    sys.exit(
        f"bench_check: profiled-run overhead {overhead:.2f}% > 3% "
        "(span recorder / clip counters too hot)"
    )
print(
    f"bench_check OK: profiled-run overhead {overhead:+.2f}% (<= 3%), "
    f"dropped spans {fmt(e.get('profile_dropped_spans'), '')}, "
    f"clip rate {fmt(e.get('clip_rate_mobimini'), '')}"
)

# Serving-metrics + drift-sampling overhead gate: forward_monitored at the
# default 1/16 drift cadence plus the batcher's per-batch registry
# publishing must stay within 1% of the plain b8 forward, measured
# back-to-back in the same bench process (bit-identity is asserted there).
mover = e.get("metrics_overhead_pct")
if not isinstance(mover, (int, float)):
    sys.exit("bench_check: BENCH_engine.json lacks metrics_overhead_pct")
if mover > 1.0:
    sys.exit(
        f"bench_check: metrics+drift overhead {mover:.2f}% > 1% "
        "(registry publish / drift sweep too hot)"
    )
print(
    f"bench_check OK: metrics+drift overhead {mover:+.2f}% (<= 1%), "
    f"drift false positives {fmt(e.get('drift_false_positive_nodes'), '')}, "
    f"shift flagged {e.get('drift_shifted_flagged')}"
)

# Robustness overhead gate: with fault hooks OFF, the PR 9 serving armor
# (admission-gate load + deadline check + unwind boundary around every
# dispatch) must stay within 1% of the bare b8 forward, measured
# back-to-back in the same bench process. Fault tolerance is only free if
# the happy path can't tell it's there.
rover = e.get("robustness_overhead_pct")
if not isinstance(rover, (int, float)):
    sys.exit("bench_check: BENCH_engine.json lacks robustness_overhead_pct")
if rover > 1.0:
    sys.exit(
        f"bench_check: robustness overhead {rover:.2f}% > 1% "
        "(unwind boundary / deadline check too hot)"
    )
print(
    f"bench_check OK: robustness overhead {rover:+.2f}% (<= 1%), "
    f"overload goodput {fmt(e.get('serve_overload_goodput_sps'), ' sps')}, "
    f"shed rate {fmt(e.get('serve_shed_rate'), '')}, "
    f"deadline miss rate {fmt(e.get('serve_deadline_miss_rate'), '')}"
)

print(
    f"bench_check OK: engine batched {speedup:.2f}x fp32 (>= 1.5), "
    f"batch scaling {scaling:.2f}x (>= 2.0), "
    f"vs quantsim {fmt(e.get('engine_speedup_vs_quantsim_b8'))}, "
    f"allocs/forward {allocs:g} (= 0), "
    f"arena peak {fmt(e.get('arena_peak_bytes_b8'), ' B')}, "
    f"max step deviation {fmt(e.get('max_step_deviation'), '')}"
)

# --- Throughput ratchet against BENCH_history.jsonl -----------------------
# Every successful gate run appends one JSON line; the next run must keep
# batch-8 engine throughput >= 0.9x the last recorded value. The first run
# (empty/missing history) just starts the trajectory.
import os
import time

# A missing or empty history file is the normal first run, and a corrupt
# last line (truncated append, merge artifact) must not brick the gate:
# both cases just start a fresh baseline instead of exiting.
hist_path = "BENCH_history.jsonl"
prev = None
if os.path.exists(hist_path):
    with open(hist_path) as f:
        lines = [ln for ln in f if ln.strip()]
    if lines:
        try:
            prev = json.loads(lines[-1])
        except json.JSONDecodeError:
            print(
                f"bench_check: {hist_path} last line is not valid JSON — "
                "ignoring history, recording this run as the new baseline",
                file=sys.stderr,
            )

cur = e.get("engine_b8_sps")
# Entries are host-dependent: only ratchet against a previous run with the
# same worker-thread count (a laptop→CI or AIMET_THREADS change is not a
# code regression) AND the same SIMD dispatch tier (an AVX2 laptop run is
# no baseline for a forced-scalar or SSE-only run, and vice versa). A
# mismatched entry still gets superseded by this run. Legacy lines predate
# tier recording; treat their tier as unknown-but-equal only if this run
# also lacks one.
comparable = (
    prev is not None
    and isinstance(prev.get("engine_b8_sps"), (int, float))
    and prev.get("threads") == e.get("threads")
    and prev.get("simd_tier") == e.get("simd_tier")
)
if comparable:
    floor = 0.9 * prev["engine_b8_sps"]
    if not isinstance(cur, (int, float)) or cur < floor:
        sys.exit(
            f"bench_check: engine b8 throughput {cur} sps fell below 0.9x the "
            f"previous run ({prev['engine_b8_sps']:.1f} sps; floor {floor:.1f})"
        )
    print(
        f"bench_check OK: ratchet {cur:.1f} sps vs previous "
        f"{prev['engine_b8_sps']:.1f} sps (floor {floor:.1f})"
    )
    # Per-model ratchets (multi-branch wavefront models) — only once a
    # comparable previous run has recorded them.
    for key in ("engine_b8_sps_detmini", "engine_b8_sps_segmini"):
        base = prev.get(key)
        val = e.get(key)
        if isinstance(base, (int, float)):
            mfloor = 0.9 * base
            if not isinstance(val, (int, float)) or val < mfloor:
                sys.exit(
                    f"bench_check: {key} {val} sps fell below 0.9x the "
                    f"previous run ({base:.1f} sps; floor {mfloor:.1f})"
                )
            print(
                f"bench_check OK: ratchet {key} {val:.1f} sps vs previous "
                f"{base:.1f} sps (floor {mfloor:.1f})"
            )
elif prev is not None:
    print(
        "bench_check: previous history entry ran with different threads/tier "
        f"({prev.get('threads')}/{prev.get('simd_tier')} vs "
        f"{e.get('threads')}/{e.get('simd_tier')}) — ratchet skipped, "
        "recording this run as the new baseline"
    )
else:
    print("bench_check: no prior BENCH_history.jsonl entry — starting the ratchet")

entry = {
    "ts": int(time.time()),
    "engine_b1_sps": e.get("engine_b1_sps"),
    "engine_b8_sps": e.get("engine_b8_sps"),
    "engine_batched_speedup_vs_fp32": speedup,
    "serve_b8_sps": e.get("serve_b8_sps"),
    "allocs_per_forward_b8": allocs,
    "arena_peak_bytes_b8": e.get("arena_peak_bytes_b8"),
    "max_step_deviation": e.get("max_step_deviation"),
    "quantsim_over_fp32": ratio,
    "mac_reduction_pct": reduction,
    "threads": e.get("threads"),
    "simd_tier": e.get("simd_tier"),
    "gemm_gops": e.get("gemm_gops"),
    "gemm_w4a8_gops": e.get("gemm_w4a8_gops"),
    "weight_bytes_mobimini": e.get("weight_bytes_mobimini"),
    "weight_bytes_detmini": e.get("weight_bytes_detmini"),
    "weight_bytes_segmini": e.get("weight_bytes_segmini"),
    "amp_weight_reduction_pct": amp_red,
    "amp_eval_delta": amp_delta,
    "engine_b8_sps_detmini": e.get("engine_b8_sps_detmini"),
    "engine_b8_sps_segmini": e.get("engine_b8_sps_segmini"),
    "wavefronts": e.get("wavefronts"),
    "profile_overhead_pct": overhead,
    "metrics_overhead_pct": mover,
    "robustness_overhead_pct": rover,
    "serve_shed_rate": e.get("serve_shed_rate"),
    "serve_deadline_miss_rate": e.get("serve_deadline_miss_rate"),
    "serve_overload_goodput_sps": e.get("serve_overload_goodput_sps"),
    "serve_overload_shed_frac": e.get("serve_overload_shed_frac"),
    "serve_overload_p99_ms": e.get("serve_overload_p99_ms"),
    "drift_false_positive_nodes": e.get("drift_false_positive_nodes"),
    "serve_b8_fill_ratio": e.get("serve_b8_fill_ratio"),
    "clip_rate_mobimini": e.get("clip_rate_mobimini"),
    "clip_rate_detmini": e.get("clip_rate_detmini"),
    "clip_rate_segmini": e.get("clip_rate_segmini"),
}
with open(hist_path, "a") as f:
    f.write(json.dumps(entry) + "\n")
print(f"bench_check: appended run to {hist_path}")
EOF
