#!/usr/bin/env python3
"""Validate a Prometheus text-format (0.0.4) exposition file.

Line-format checker for the serve-bench smoke in ci.sh: every line must
be a `# HELP`, `# TYPE`, blank, or sample line; every sample's metric
family must have a preceding TYPE declaration (summary samples may use
the family's `_sum` / `_count` suffixes); and every sample value must
parse as a float or one of the spellings `+Inf` / `-Inf` / `NaN`.

Exits nonzero with a `file:line: message` diagnostic on the first
violation, silently (exit 0) otherwise.
"""

import re
import sys

# Metric and label names per the exposition-format spec.
NAME_RE = re.compile(r"[a-zA-Z_:][a-zA-Z0-9_:]*")
LABEL_RE = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\["\\n])*)"')
SPECIAL_VALUES = {"+Inf", "-Inf", "Inf", "NaN"}


def fail(path, lineno, msg):
    sys.exit(f"{path}:{lineno}: {msg}")


def parse_labels(path, lineno, body):
    """Validate the {...} label body of a sample line."""
    pos = 0
    while pos < len(body):
        m = LABEL_RE.match(body, pos)
        if not m:
            fail(path, lineno, f"malformed label at ...{body[pos:]!r}")
        pos = m.end()
        if pos < len(body):
            if body[pos] != ",":
                fail(path, lineno, f"expected ',' between labels, got {body[pos]!r}")
            pos += 1


def check(path):
    with open(path) as f:
        lines = f.read().split("\n")
    # Trailing newline produces one empty final element; that is fine.
    typed = {}  # family name -> declared type
    samples = 0
    for lineno, line in enumerate(lines, 1):
        if line == "":
            continue
        if line.startswith("# HELP "):
            rest = line[len("# HELP ") :]
            name = rest.split(" ", 1)[0]
            if not NAME_RE.fullmatch(name):
                fail(path, lineno, f"bad metric name in HELP: {name!r}")
            continue
        if line.startswith("# TYPE "):
            parts = line[len("# TYPE ") :].split(" ")
            if len(parts) != 2:
                fail(path, lineno, f"TYPE line needs 'name kind': {line!r}")
            name, kind = parts
            if not NAME_RE.fullmatch(name):
                fail(path, lineno, f"bad metric name in TYPE: {name!r}")
            if kind not in ("counter", "gauge", "summary", "histogram", "untyped"):
                fail(path, lineno, f"unknown metric type {kind!r}")
            typed[name] = kind
            continue
        if line.startswith("#"):
            fail(path, lineno, f"comment line is neither HELP nor TYPE: {line!r}")

        # Sample line: name[{labels}] value  — split at the LAST space so
        # label values containing spaces survive.
        body, sep, value = line.rpartition(" ")
        if not sep or not body:
            fail(path, lineno, f"sample line has no value: {line!r}")
        if value not in SPECIAL_VALUES:
            try:
                float(value)
            except ValueError:
                fail(path, lineno, f"unparseable sample value {value!r}")

        m = NAME_RE.match(body)
        if not m:
            fail(path, lineno, f"sample line has no metric name: {line!r}")
        name = m.group(0)
        rest = body[m.end() :]
        if rest:
            if not (rest.startswith("{") and rest.endswith("}")):
                fail(path, lineno, f"malformed label block: {rest!r}")
            parse_labels(path, lineno, rest[1:-1])

        # Summary families expose `<name>_sum` / `<name>_count` samples and
        # quantile samples under the bare family name.
        family = name
        for suffix in ("_sum", "_count", "_bucket"):
            if name.endswith(suffix) and name[: -len(suffix)] in typed:
                family = name[: -len(suffix)]
                break
        if family not in typed:
            fail(path, lineno, f"sample {name!r} has no preceding # TYPE")
        samples += 1

    if samples == 0:
        fail(path, 0, "exposition contains no samples")
    print(f"check_prom OK: {path}: {samples} samples across {len(typed)} families")


if __name__ == "__main__":
    if len(sys.argv) != 2:
        sys.exit("usage: check_prom.py <exposition.prom>")
    check(sys.argv[1])
