"""Repo-root pytest shim: make `pytest python/tests/` work from the
workspace root (the Makefile runs pytest from python/, where the
`compile` package resolves naturally)."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "python"))
