//! The PTQ debugging flow (fig 4.5) on a deliberately broken model:
//! W4 weights, no CLE, on the pathological MobiMini — then follows the
//! flow's own advice and shows the fix working.
//!
//! Run: `cargo run --release --example debug_flow`

use aimet::coordinator::experiments::{trained_model, Effort};
use aimet::ptq::{run_debug_flow, standard_ptq_pipeline, BiasCorrection, PtqOptions};
use aimet::quantsim::QuantParams;
use aimet::task::{evaluate_graph, evaluate_sim};

fn main() {
    let model = "mobimini";
    println!("== fig 4.5 debugging flow ==");
    let (g, data, _) = trained_model(model, Effort::Fast, 999);
    let fp32 = evaluate_graph(&g, model, &data, 4, 16).unwrap();
    let calib = data.calibration(3, 16);

    // A broken configuration: W4 per-tensor, no CLE, min-max everywhere.
    let broken = standard_ptq_pipeline(
        &g,
        &calib,
        &PtqOptions {
            qp: QuantParams {
                param_bw: 4,
                ..Default::default()
            },
            use_cle: false,
            bias_correction: BiasCorrection::None,
            ..Default::default()
        },
    );
    let report = run_debug_flow(&broken.sim, fp32, &|sim| {
        evaluate_sim(sim, model, &data, 2, 16).unwrap()
    });
    print!("{}", report.render());

    // Follow the advice: CLE + AdaRound at the same bit-width.
    println!("\n== applying the flow's advice (CLE + AdaRound at W4) ==");
    let mut fixed_opts = PtqOptions {
        qp: QuantParams {
            param_bw: 4,
            ..Default::default()
        },
        use_adaround: true,
        ..Default::default()
    };
    fixed_opts.adaround.iterations = 200;
    let fixed = standard_ptq_pipeline(&g, &calib, &fixed_opts);
    let before = report.full_quant_metric;
    let after = evaluate_sim(&fixed.sim, model, &data, 4, 16).unwrap();
    println!("broken W4 sim : {before:.2}");
    println!("fixed  W4 sim : {after:.2}  (fp32 {fp32:.2})");
}
