use aimet::coordinator::experiments::*;
fn main() {
    let rows = table_4_2(Effort::Fast);
    print!("{}", render_table_4_2(&rows));
    let r51 = table_5_1(Effort::Fast);
    print!("{}", render_table_5_1(&r51));
    let r52 = table_5_2(Effort::Fast);
    print!("{}", render_table_5_2(&r52));
}
