//! Encodings export (§3.3, code block 3.3): create a sim, calibrate,
//! export the plain model + JSON encodings, and show what an on-target
//! runtime would import.
//!
//! Run: `cargo run --release --example export_encodings [model]`

use aimet::quantsim::{load_param_encodings, QuantParams, QuantizationSimModel};
use aimet::task::TaskData;
use aimet::zoo;

fn main() {
    let model = std::env::args().nth(1).unwrap_or_else(|| "mobimini".into());
    let g = zoo::build(&model, 4242).expect("zoo model");
    let data = TaskData::new(&model, 4243).unwrap();
    let mut sim = QuantizationSimModel::with_defaults(g, QuantParams::default());
    sim.compute_encodings(&data.calibration(4, 16));

    let dir = std::env::temp_dir().join("aimet_export_demo");
    sim.export(&dir, &model).expect("export");
    println!("exported to {}:", dir.display());
    println!("  {model}.json / {model}.bin   — the plain FP32 model (no sim ops)");
    println!("  {model}_encodings.json       — scale/offset per tensor\n");

    let enc = std::fs::read_to_string(dir.join(format!("{model}_encodings.json"))).unwrap();
    // Show the first ~20 lines, like the AIMET docs do.
    for line in enc.lines().take(20) {
        println!("{line}");
    }
    println!("…");

    // Round-trip: an "on-target runtime" imports the encodings.
    let params = load_param_encodings(&enc).unwrap();
    println!(
        "\nre-imported {} parameter encodings; example: stem layer scale = {:.6}",
        params.len(),
        params.values().next().unwrap().encodings[0].scale
    );
}
