//! End-to-end driver — the full three-layer system on a real workload.
//!
//! 1. Trains MobiMini FP32 on SynthImageNet **through the PJRT artifact**
//!    (`mobimini_fp32_step`, the JAX L2 train step AOT-lowered to HLO) for
//!    a few hundred steps, logging the loss curve. Python never runs.
//! 2. Calibrates a quantization sim and runs the fig 4.1 PTQ pipeline.
//! 3. QAT fine-tunes with STE (chapter 5) from the PTQ init.
//! 4. Prints a Table-4.1/5.1-shaped report; the run is recorded in
//!    EXPERIMENTS.md §End-to-end.
//!
//! Requires `make artifacts`. Run:
//! `cargo run --release --example e2e_quantize [steps]`

use aimet::ptq::{standard_ptq_pipeline, PtqOptions};
use aimet::qat::{fit_qat, TrainConfig};
use aimet::runtime::{graph_param_tensors, set_graph_params, Runtime};
use aimet::task::{evaluate_graph, evaluate_sim, TaskData, Targets};
use aimet::tensor::Tensor;
use aimet::zoo;
use std::time::Instant;

fn main() {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);
    let model = "mobimini";
    let dir = Runtime::artifacts_dir();
    if !Runtime::available(&dir) {
        eprintln!("no artifacts at {} — run `make artifacts` first", dir.display());
        std::process::exit(1);
    }
    let mut rt = Runtime::open(&dir).expect("runtime");
    println!("== e2e: train (PJRT) → PTQ → QAT → report ==");

    // ---- 1. FP32 training through the AOT train-step artifact ---------
    let mut g = zoo::build(model, 1234).unwrap();
    let data = TaskData::new(model, 1235).unwrap();
    let spec = rt.spec("mobimini_fp32_step").expect("step program").clone();
    let batch = spec.inputs[spec.inputs.len() - 3][0];
    let t0 = Instant::now();
    let mut lr = 0.1f32;
    for step in 0..steps {
        if step > 0 && step % (steps / 2).max(1) == 0 {
            lr /= 10.0; // paper §5.2: divide LR by 10 on a schedule
        }
        let (x, targets) = data.batch(step as u64, batch);
        let Targets::Labels(labels) = targets else { unreachable!() };
        let mut y = Tensor::zeros(&[batch, zoo::CLS_CLASSES]);
        for (i, &l) in labels.iter().enumerate() {
            y.data_mut()[i * zoo::CLS_CLASSES + l] = 1.0;
        }
        let mut inputs = graph_param_tensors(&g);
        inputs.push(x);
        inputs.push(y);
        inputs.push(Tensor::scalar(lr));
        let outs = rt.execute("mobimini_fp32_step", &inputs).expect("train step");
        let k = outs.len() - 1;
        set_graph_params(&mut g, &outs[..k]);
        if step % 25 == 0 || step + 1 == steps {
            println!(
                "step {step:>4}  loss {:.4}  lr {lr:.0e}  ({:.1} steps/s)",
                outs[k].data()[0],
                (step + 1) as f64 / t0.elapsed().as_secs_f64()
            );
        }
    }
    let fp32 = evaluate_graph(&g, model, &data, 6, 16).unwrap();
    println!(
        "FP32 after {steps} PJRT steps: top-1 {fp32:.2}% ({:.1}s)",
        t0.elapsed().as_secs_f64()
    );

    // ---- 2. PTQ (fig 4.1) ---------------------------------------------
    let calib = data.calibration(4, 16);
    let rtn = standard_ptq_pipeline(
        &g,
        &calib,
        &PtqOptions {
            use_cle: false,
            bias_correction: aimet::ptq::BiasCorrection::None,
            ..Default::default()
        },
    );
    let rtn_acc = evaluate_sim(&rtn.sim, model, &data, 6, 16).unwrap();
    let ptq_out = standard_ptq_pipeline(&g, &calib, &PtqOptions::default());
    for line in &ptq_out.log {
        println!("ptq: {line}");
    }
    let ptq = evaluate_sim(&ptq_out.sim, model, &data, 6, 16).unwrap();

    // ---- 3. QAT (fig 5.2) ---------------------------------------------
    let mut sim = ptq_out.sim.clone();
    let cfg = TrainConfig {
        steps: steps / 2,
        lr: 0.01,
        lr_decay_every: steps / 4,
        ..Default::default()
    };
    let qlog = fit_qat(&mut sim, model, &data, &cfg);
    println!("qat: {} points, final loss {:.4}", qlog.points.len(), qlog.final_loss());
    let qat = evaluate_sim(&sim, model, &data, 6, 16).unwrap();

    // ---- 4. Report ------------------------------------------------------
    println!("\n== report (top-1 %) ==");
    println!("FP32 baseline        : {fp32:6.2}");
    println!("W8/A8 round-to-near  : {rtn_acc:6.2}");
    println!("W8/A8 PTQ (CLE/BC)   : {ptq:6.2}");
    println!("W8/A8 QAT            : {qat:6.2}");
    let out = std::env::temp_dir().join("aimet_e2e");
    sim.export(&out, model).expect("export");
    println!("exported final model + encodings to {}", out.display());
}
