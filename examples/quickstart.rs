//! Quickstart — the 60-second AIMET tour (code block 3.1 in Rust).
//!
//! Builds a model, creates a `QuantizationSimModel`, calibrates encodings
//! from representative data, and evaluates the simulated W8/A8 accuracy as
//! a drop-in replacement for the FP32 model. Also prints the fig 2.3
//! quantization-grid demo.
//!
//! Run: `cargo run --release --example quickstart`

use aimet::quant::{Encoding, Quantizer};
use aimet::quantsim::{QuantParams, QuantizationSimModel};
use aimet::task::{evaluate_graph, evaluate_sim, TaskData};
use aimet::tensor::Tensor;
use aimet::zoo;

fn main() {
    // --- fig 2.3: asymmetric vs symmetric uniform grids ----------------
    println!("== quantization grids (fig 2.3, b = 4 for legibility) ==");
    let x = Tensor::new(&[9], vec![-1.0, -0.6, -0.3, -0.05, 0.0, 0.2, 0.5, 0.8, 1.2]);
    for (label, enc) in [
        ("asymmetric", Encoding::from_min_max(-1.0, 1.2, 4, false)),
        ("symmetric signed", Encoding::from_min_max(-1.0, 1.2, 4, true)),
        ("symmetric unsigned", Encoding::from_min_max(0.0, 1.2, 4, true)),
    ] {
        let q = Quantizer::per_tensor(enc).qdq(&x);
        println!(
            "{label:<19} s={:.4} z={:<3} -> {:?}",
            enc.scale,
            enc.offset,
            q.data().iter().map(|v| (v * 100.0).round() / 100.0).collect::<Vec<_>>()
        );
    }

    // --- code block 3.1: QuantizationSimModel --------------------------
    println!("\n== quantization simulation (code block 3.1) ==");
    let model = "resmini";
    let g = zoo::build(model, 7).expect("zoo model");
    let data = TaskData::new(model, 8).unwrap();

    let fp32 = evaluate_graph(&g, model, &data, 4, 16).unwrap();
    println!("FP32 {model}: top-1 {fp32:.2}% (untrained weights — quickstart only)");

    // sim = QuantizationSimModel(model, default_output_bw=8, default_param_bw=8)
    let mut sim = QuantizationSimModel::with_defaults(g, QuantParams::default());
    let (na, np) = sim.quantizer_counts();
    println!("inserted {na} activation + {np} parameter quantizers");

    // sim.compute_encodings(forward_pass_callback=send_samples)
    sim.compute_encodings(&data.calibration(4, 16));

    // quantized_accuracy = eval_function(model=sim.model)
    let quantized = evaluate_sim(&sim, model, &data, 4, 16).unwrap();
    println!("W8/A8 sim: top-1 {quantized:.2}%  (drop {:+.2})", quantized - fp32);

    // Export (§3.3): model + JSON encodings for an on-target runtime.
    let out = std::env::temp_dir().join("aimet_quickstart");
    sim.export(&out, model).expect("export");
    println!("exported model + encodings to {}", out.display());
}
