//! The standard PTQ pipeline (fig 4.1) narrated step by step, with an
//! ablation over each stage: RTN only → +CLE → +BC → +AdaRound.
//!
//! Run: `cargo run --release --example ptq_pipeline [model]`

use aimet::coordinator::experiments::{trained_model, Effort};
use aimet::ptq::{standard_ptq_pipeline, AdaroundParameters, BiasCorrection, PtqOptions};
use aimet::quant::QuantScheme;
use aimet::task::{evaluate_graph, evaluate_sim};

fn main() {
    let model = std::env::args().nth(1).unwrap_or_else(|| "mobimini".into());
    println!("== fig 4.1 standard PTQ pipeline on {model} ==");
    let (g, data, _) = trained_model(&model, Effort::Fast, 777);
    let fp32 = evaluate_graph(&g, &model, &data, 6, 16).unwrap();
    println!("FP32 baseline: {fp32:.2}");
    let calib = data.calibration(4, 16);

    let variants: Vec<(&str, PtqOptions)> = vec![
        (
            "RTN only (min-max, no CLE/BC)",
            PtqOptions {
                use_cle: false,
                bias_correction: BiasCorrection::None,
                weight_scheme: QuantScheme::Tf,
                act_scheme: QuantScheme::Tf,
                ..Default::default()
            },
        ),
        (
            "+ SQNR range setting",
            PtqOptions {
                use_cle: false,
                bias_correction: BiasCorrection::None,
                ..Default::default()
            },
        ),
        (
            "+ CLE",
            PtqOptions {
                bias_correction: BiasCorrection::None,
                ..Default::default()
            },
        ),
        ("+ empirical bias correction", PtqOptions::default()),
        (
            "+ AdaRound",
            PtqOptions {
                use_adaround: true,
                adaround: AdaroundParameters {
                    iterations: 200,
                    ..Default::default()
                },
                ..Default::default()
            },
        ),
    ];

    println!("{:<34} {:>8} {:>8}", "pipeline stage", "top-1 %", "Δ fp32");
    for (label, opts) in variants {
        let out = standard_ptq_pipeline(&g, &calib, &opts);
        let acc = evaluate_sim(&out.sim, &model, &data, 6, 16).unwrap();
        println!("{label:<34} {acc:>8.2} {:>+8.2}", acc - fp32);
    }
}
