//! Figures 4.2/4.3 — per-channel weight ranges of the first depthwise
//! layer before and after cross-layer equalization, as ASCII boxplots and
//! CSV (written next to the binary for plotting).
//!
//! Run: `cargo run --release --example cle_visualize`

use aimet::coordinator::experiments::{fig_4_2_4_3, render_fig_4_2_4_3, Effort};

fn main() {
    let res = fig_4_2_4_3(Effort::Fast);
    print!("{}", render_fig_4_2_4_3(&res));
    let dir = std::env::temp_dir().join("aimet_cle_ranges");
    std::fs::create_dir_all(&dir).ok();
    std::fs::write(dir.join("fig4_2_before.csv"), res.before.to_csv()).unwrap();
    std::fs::write(dir.join("fig4_3_after.csv"), res.after.to_csv()).unwrap();
    println!("CSV written to {}", dir.display());
}
