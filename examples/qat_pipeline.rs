//! The QAT pipeline (fig 5.2): PTQ-initialized STE fine-tuning at W8 and
//! W4, showing where QAT pays off over PTQ (chapter 5's motivation).
//!
//! Run: `cargo run --release --example qat_pipeline [model]`

use aimet::coordinator::experiments::{trained_model, Effort};
use aimet::ptq::{standard_ptq_pipeline, PtqOptions};
use aimet::qat::{fit_qat, TrainConfig};
use aimet::quantsim::QuantParams;
use aimet::task::{evaluate_graph, evaluate_sim};

fn main() {
    let model = std::env::args().nth(1).unwrap_or_else(|| "resmini".into());
    println!("== fig 5.2 QAT pipeline on {model} ==");
    let (g, data, _) = trained_model(&model, Effort::Fast, 888);
    let fp32 = evaluate_graph(&g, &model, &data, 6, 16).unwrap();
    println!("FP32 baseline: {fp32:.2}\n");
    let calib = data.calibration(4, 16);

    println!(
        "{:<8} {:>10} {:>10} {:>10}",
        "config", "PTQ", "QAT", "Δ(QAT-PTQ)"
    );
    for (w_bw, a_bw) in [(8u32, 8u32), (4, 8)] {
        let opts = PtqOptions {
            qp: QuantParams {
                param_bw: w_bw,
                act_bw: a_bw,
                ..Default::default()
            },
            ..Default::default()
        };
        // Fig 5.2 steps: CLE → add quantizers → range setting (all inside
        // the PTQ pipeline) → train → export.
        let ptq_out = standard_ptq_pipeline(&g, &calib, &opts);
        let ptq = evaluate_sim(&ptq_out.sim, &model, &data, 6, 16).unwrap();
        let mut sim = ptq_out.sim.clone();
        let cfg = TrainConfig {
            steps: 150,
            lr: 0.01,
            lr_decay_every: 75,
            ..Default::default()
        };
        fit_qat(&mut sim, &model, &data, &cfg);
        let qat = evaluate_sim(&sim, &model, &data, 6, 16).unwrap();
        println!(
            "W{w_bw}/A{a_bw}   {ptq:>10.2} {qat:>10.2} {:>+10.2}",
            qat - ptq
        );
    }
}
