"""L1 Pallas kernel: fake quantization (quantize-dequantize, fig 3.1).

The simulation op the whole toolkit is built on. It is memory-bound and
elementwise, so the TPU mapping is a tiled 2-D streaming kernel: each grid
step pulls one (BLOCK_M, BLOCK_N) tile of the tensor HBM->VMEM, applies the
branch-free qdq (round, clip, shift, rescale -- all VPU ops), and streams it
back. Scale/zero-point ride along as tiny (1,1) / (C,1) blocks that every
grid step maps to the same VMEM-resident slot.

Hardware adaptation (DESIGN.md section Hardware-Adaptation): AIMET's C++
backend runs this on the host; on a fixed-point accelerator it *is* the
requantize unit of fig 2.2. Here the BlockSpec expresses the HBM<->VMEM
schedule; interpret=True keeps it executable on the CPU PJRT client.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile: 256x256 f32 = 256 KiB VMEM per operand slot, far under the
# ~16 MiB VMEM budget even with double buffering (DESIGN.md section Perf).
BLOCK_M = 256
BLOCK_N = 256


def _qdq_kernel(x_ref, s_ref, z_ref, o_ref, *, int_min, int_max):
    s = s_ref[0, 0]
    z = z_ref[0, 0]
    q = jnp.clip(jnp.round(x_ref[...] / s) + z, int_min, int_max)
    o_ref[...] = (q - z) * s


def _qdq_kernel_per_channel(x_ref, s_ref, z_ref, o_ref, *, int_min, int_max):
    s = s_ref[...]  # [bc, 1] broadcasts down the row tile
    z = z_ref[...]
    q = jnp.clip(jnp.round(x_ref[...] / s) + z, int_min, int_max)
    o_ref[...] = (q - z) * s


def _pad2(x2, bm, bn):
    m, n = x2.shape
    pm = (-m) % bm
    pn = (-n) % bn
    if pm or pn:
        x2 = jnp.pad(x2, ((0, pm), (0, pn)))
    return x2, m, n


@functools.partial(jax.jit, static_argnames=("int_min", "int_max"))
def fake_quant(x, scale, zero_point, *, int_min, int_max):
    """Per-tensor qdq of an arbitrary-rank tensor.

    `scale`/`zero_point` are scalars (Python or 0-d); `int_min`/`int_max`
    are the static integer-grid bounds (asymmetric: 0..2^b-1, symmetric
    signed: -(2^{b-1}-1)..2^{b-1}-1).
    """
    shape = x.shape
    flat = x.reshape((-1,))
    # Lay the tensor out as [M, N] tiles.
    n = min(flat.shape[0], BLOCK_N)
    m = -(-flat.shape[0] // n)
    x2, m0, n0 = _pad2(jnp.pad(flat, (0, m * n - flat.shape[0])).reshape(m, n), 1, 1)
    bm = min(BLOCK_M, x2.shape[0])
    bn = min(BLOCK_N, x2.shape[1])
    x2, _, _ = _pad2(x2, bm, bn)
    grid = (x2.shape[0] // bm, x2.shape[1] // bn)
    s = jnp.asarray(scale, jnp.float32).reshape(1, 1)
    z = jnp.asarray(zero_point, jnp.float32).reshape(1, 1)
    out = pl.pallas_call(
        functools.partial(_qdq_kernel, int_min=float(int_min), int_max=float(int_max)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, jnp.float32),
        interpret=True,
    )(x2, s, z)
    return out[:m0, :n0].reshape(-1)[: flat.shape[0]].reshape(shape)


@functools.partial(jax.jit, static_argnames=("int_min", "int_max"))
def fake_quant_per_channel(x, scales, zero_points, *, int_min, int_max):
    """Per-channel (axis 0) qdq of a weight tensor [C, ...] (section 2.3).

    `scales`/`zero_points` have shape [C]. Channels map to tile rows so a
    [bc, 1] scale block broadcasts across each channel's row in VMEM.
    """
    c = x.shape[0]
    flat = x.reshape(c, -1)
    bn = min(BLOCK_N, flat.shape[1])
    bc = min(8, c)
    x2, c0, n0 = _pad2(flat, bc, bn)
    s = jnp.pad(scales.astype(jnp.float32), (0, x2.shape[0] - c)).reshape(-1, 1)
    z = jnp.pad(zero_points.astype(jnp.float32), (0, x2.shape[0] - c)).reshape(-1, 1)
    grid = (x2.shape[0] // bc, x2.shape[1] // bn)
    out = pl.pallas_call(
        functools.partial(
            _qdq_kernel_per_channel, int_min=float(int_min), int_max=float(int_max)
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bc, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bc, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bc, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bc, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, jnp.float32),
        interpret=True,
    )(x2, s, z)
    return out[:c0, :n0].reshape(x.shape)
