"""L1 Pallas kernel: streaming min/max range statistics (section 4.4).

The observation half of quantization range setting: `compute_encodings`
feeds ~1000 calibration samples through the model and tracks each tensor's
dynamic range. This kernel is that reduction as a tiled streaming pass —
one (1, BLOCK) tile per grid step, a running (min, max) pair held in the
output VMEM slot (every grid step maps to the same (1, 2) block, the
standard Pallas sequential-reduction idiom).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 1024


def _minmax_kernel(x_ref, o_ref):
    i = pl.program_id(0)
    tile_min = jnp.min(x_ref[...])
    tile_max = jnp.max(x_ref[...])

    @pl.when(i == 0)
    def _init():
        o_ref[0, 0] = tile_min
        o_ref[0, 1] = tile_max

    @pl.when(i > 0)
    def _merge():
        o_ref[0, 0] = jnp.minimum(o_ref[0, 0], tile_min)
        o_ref[0, 1] = jnp.maximum(o_ref[0, 1], tile_max)


@functools.partial(jax.jit)
def range_stats(x):
    """Per-tensor [min, max] of an arbitrary-rank tensor, shape (2,)."""
    flat = x.reshape(1, -1)
    n = flat.shape[1]
    block = min(BLOCK, n)
    pad = (-n) % block
    if pad:
        # Pad with the first element so padding never moves min/max.
        flat = jnp.concatenate([flat, jnp.broadcast_to(flat[:, :1], (1, pad))], axis=1)
    grid = (flat.shape[1] // block,)
    out = pl.pallas_call(
        _minmax_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, block), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, 2), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 2), jnp.float32),
        interpret=True,
    )(flat)
    return out.reshape(2)
