"""Pure-jnp correctness oracles for the L1 Pallas kernels.

Each function here is the straight-line textbook definition of the paper's
math (eqs 2.4-2.8 for uniform quantization, eq 2.3 + fig 2.2 for the
quantized MAC pipeline). pytest compares every Pallas kernel against these
under hypothesis-driven shape/value sweeps; the Rust quant core implements
the same equations, so these oracles are the shared ground truth of all
three layers.
"""

import jax.numpy as jnp


def asym_grid(bw: int):
    """Unsigned asymmetric integer grid {0, ..., 2^b - 1} (eq 2.4)."""
    return 0.0, float(2**bw - 1)


def sym_grid(bw: int):
    """Signed symmetric restricted grid +/-(2^{b-1} - 1) (eq 2.8c)."""
    half = float(2 ** (bw - 1) - 1)
    return -half, half


def fake_quant_ref(x, scale, zero_point, int_min, int_max):
    """Quantize-dequantize (eq 2.7): s * (clamp(round(x/s) + z) - z).

    `scale`/`zero_point` broadcast against `x`, so the same oracle covers
    per-tensor (scalars) and per-channel (shape [C, 1, ...]) quantization.
    """
    q = jnp.clip(jnp.round(x / scale) + zero_point, int_min, int_max)
    return (q - zero_point) * scale


def quantize_ref(x, scale, zero_point, int_min, int_max):
    """Quantization only (eq 2.4): the integer-grid values as f32."""
    return jnp.clip(jnp.round(x / scale) + zero_point, int_min, int_max)


def qmatmul_ref(x_int, w_int, bias_i32, s_x, s_w, s_y, z_y, bw_out=8):
    """Integer matmul + requantization — fig 2.2's accelerator pipeline.

    x_int [M,K] and w_int [K,N] hold integer values stored as f32 (exact
    up to 2^24, simulating the INT32 accumulator); bias_i32 [N] is the
    INT32 bias already at scale s_x*s_w (eq 2.3). The output is the next
    layer's integer grid: clamp(round((s_x*s_w/s_y) * acc) + z_y).
    """
    acc = x_int @ w_int + bias_i32  # INT32 accumulator (eq 2.3)
    lo, hi = asym_grid(bw_out)
    y = jnp.round(acc * (s_x * s_w / s_y)) + z_y
    return jnp.clip(y, lo, hi)


def range_stats_ref(x):
    """Per-tensor (min, max) — the observation step of range setting (4.4)."""
    return jnp.stack([jnp.min(x), jnp.max(x)])
