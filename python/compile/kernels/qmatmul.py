"""L1 Pallas kernel: quantized matmul + requantize (figs 2.1/2.2).

The accelerator MAC pipeline of the paper's hardware chapter, expressed as
one Pallas kernel:

  * the (bm, K) x (K, bn) `jnp.dot` tile is the PE array / MXU step —
    integer products accumulated exactly (f32 holds integers exactly up to
    2^24, standing in for the INT32 accumulators of fig 2.2);
  * the per-output-channel bias load is the accumulator initialisation
    A_n = b_n of eq 2.1;
  * the final rescale by s_x*s_w/s_y + zero-point + clamp is the
    *requantization* unit that returns activations to INT8 before they are
    written back to memory.

Hardware adaptation: the paper's fixed-point accelerator streams weights
and activations through a systolic array; on TPU the analogous schedule is
(bm, K)/(K, bn) VMEM tiles feeding the 128x128 MXU, with the requantize
fused into the same kernel so the INT32 accumulator never round-trips to
HBM. interpret=True keeps the kernel runnable on the CPU PJRT client.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-aligned tile sizes (128-lane); one (bm,K)+(K,bn)+(bm,bn) f32 tile set
# at K=512 is ~0.6 MiB VMEM — comfortably double-bufferable.
BLOCK_M = 128
BLOCK_N = 128


def _qmatmul_kernel(x_ref, w_ref, b_ref, s_ref, o_ref, *, out_min, out_max):
    # PE-array step: integer MAC with exact accumulation (eq 2.3).
    acc = jnp.dot(x_ref[...], w_ref[...]) + b_ref[...]
    # Requantization step (fig 2.2): INT32 -> INT8 of the next layer.
    requant = s_ref[0, 0]  # s_x*s_w/s_y
    zp = s_ref[0, 1]
    o_ref[...] = jnp.clip(jnp.round(acc * requant) + zp, out_min, out_max)


@functools.partial(jax.jit, static_argnames=("bw_out",))
def qmatmul(x_int, w_int, bias_i32, s_x, s_w, s_y, z_y, *, bw_out=8):
    """Quantized matmul: integer grids in, requantized integer grid out.

    x_int [M, K], w_int [K, N] and bias_i32 [N] hold integer values as f32
    (the INT32-accumulator simulation); scales are f32 scalars.
    """
    m, k = x_int.shape
    k2, n = w_int.shape
    assert k == k2
    bm = min(BLOCK_M, m)
    bn = min(BLOCK_N, n)
    pm, pn = (-m) % bm, (-n) % bn
    x_p = jnp.pad(x_int, ((0, pm), (0, 0)))
    w_p = jnp.pad(w_int, ((0, 0), (0, pn)))
    b_p = jnp.pad(bias_i32, (0, pn)).reshape(1, -1)
    requant = jnp.stack([s_x * s_w / s_y, z_y]).astype(jnp.float32).reshape(1, 2)
    lo, hi = 0.0, float(2**bw_out - 1)
    grid = (x_p.shape[0] // bm, w_p.shape[1] // bn)
    out = pl.pallas_call(
        functools.partial(_qmatmul_kernel, out_min=lo, out_max=hi),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, 2), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((x_p.shape[0], w_p.shape[1]), jnp.float32),
        interpret=True,
    )(x_p, w_p, b_p, requant)
    return out[:m, :n]
