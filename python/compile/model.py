"""L2: the zoo's JAX compute graphs, mirrored 1:1 from `rust/src/zoo.rs`.

The Rust coordinator owns the model *weights* (its graph IR); the JAX side
owns the *computation*. Every program lowered by `aot.py` takes the
flattened parameter list as runtime inputs in the exact order of
`rust/src/runtime.rs::graph_param_tensors` (conv/linear -> [weight, bias],
batchnorm -> [gamma, beta, mean, var], lstm -> [w_ih, w_hh, bias]), so the
Rust engine can feed its own weights through the PJRT artifacts and
cross-validate numerics engine-against-engine.

The architecture is expressed as a node table (the same IR shape as the
Rust `Graph`) and interpreted by `forward`; the quantsim variant threads
encodings through the L1 Pallas fake-quant kernel, reproducing fig 3.1's
quantizer placement under the default runtime config (supergroup fusion
included).
"""

from collections import namedtuple

import jax
import jax.numpy as jnp
from jax import lax

from .kernels.fake_quant import fake_quant

# ---------------------------------------------------------------------
# Architecture tables (node lists in Rust Graph order).
# ---------------------------------------------------------------------

# inputs: list of node indices, or "x" for the graph input.
Node = namedtuple("Node", "name kind inputs cfg")

CLS_CLASSES = 10
SEG_CLASSES = 6
DET_CLASSES = 4
SPEECH_FEATS = 8
SPEECH_TOKENS = 6
SPEECH_T = 20
LSTM_HIDDEN = 16


def _seq(nodes_spec):
    """Build a sequential-by-default node list from (name, kind, cfg[, inputs])."""
    nodes = []
    for spec in nodes_spec:
        name, kind, cfg = spec[0], spec[1], spec[2]
        inputs = spec[3] if len(spec) > 3 else (["x"] if not nodes else [len(nodes) - 1])
        nodes.append(Node(name, kind, inputs, cfg))
    return nodes


def mobimini_arch():
    n = []
    n += [("stem.conv", "conv", dict(o=16, i=3, k=3, stride=2, pad=1))]
    n += [("stem.bn", "bn", dict(c=16)), ("stem.relu6", "relu6", {})]
    for b, (cin, cout, stride) in enumerate([(16, 32, 2), (32, 64, 2), (64, 64, 1)]):
        s = f"b{b + 1}"
        n += [(f"{s}.dw", "dwconv", dict(c=cin, k=3, stride=stride, pad=1))]
        n += [(f"{s}.dw_bn", "bn", dict(c=cin)), (f"{s}.dw_relu6", "relu6", {})]
        n += [(f"{s}.pw", "conv", dict(o=cout, i=cin, k=1, stride=1, pad=0))]
        n += [(f"{s}.pw_bn", "bn", dict(c=cout)), (f"{s}.pw_relu6", "relu6", {})]
    n += [("gap", "gap", {}), ("fc", "linear", dict(o=CLS_CLASSES, i=64))]
    return _seq(n)


def resmini_arch():
    n = _seq(
        [
            ("stem.conv", "conv", dict(o=16, i=3, k=3, stride=2, pad=1)),
            ("stem.bn", "bn", dict(c=16)),
            ("stem.relu", "relu", {}),
        ]
    )
    prev = 2
    for stage, (cin, cout, stride) in enumerate([(16, 32, 2), (32, 64, 2)]):
        s = f"s{stage + 1}"
        base = len(n)
        n.append(Node(f"{s}.conv1", "conv", [prev], dict(o=cout, i=cin, k=3, stride=stride, pad=1)))
        n.append(Node(f"{s}.bn1", "bn", [base], dict(c=cout)))
        n.append(Node(f"{s}.relu1", "relu", [base + 1], {}))
        n.append(Node(f"{s}.conv2", "conv", [base + 2], dict(o=cout, i=cout, k=3, stride=1, pad=1)))
        n.append(Node(f"{s}.bn2", "bn", [base + 3], dict(c=cout)))
        n.append(Node(f"{s}.sc_conv", "conv", [prev], dict(o=cout, i=cin, k=1, stride=stride, pad=0)))
        n.append(Node(f"{s}.sc_bn", "bn", [base + 5], dict(c=cout)))
        n.append(Node(f"{s}.add", "add", [base + 4, base + 6], {}))
        n.append(Node(f"{s}.relu2", "relu", [base + 7], {}))
        prev = base + 8
    n.append(Node("gap", "gap", [prev], {}))
    n.append(Node("fc", "linear", [len(n) - 1], dict(o=CLS_CLASSES, i=64)))
    return n


def segmini_arch():
    return _seq(
        [
            ("enc1.conv", "conv", dict(o=16, i=3, k=3, stride=2, pad=1)),
            ("enc1.bn", "bn", dict(c=16)),
            ("enc1.relu", "relu", {}),
            ("enc2.conv", "conv", dict(o=32, i=16, k=3, stride=2, pad=1)),
            ("enc2.bn", "bn", dict(c=32)),
            ("enc2.relu", "relu", {}),
            ("mid.conv", "conv", dict(o=32, i=32, k=3, stride=1, pad=1)),
            ("mid.bn", "bn", dict(c=32)),
            ("mid.relu", "relu", {}),
            ("dec1.up", "upsample2", {}),
            ("dec1.conv", "conv", dict(o=16, i=32, k=3, stride=1, pad=1)),
            ("dec1.bn", "bn", dict(c=16)),
            ("dec1.relu", "relu", {}),
            ("dec2.up", "upsample2", {}),
            ("dec2.conv", "conv", dict(o=16, i=16, k=3, stride=1, pad=1)),
            ("dec2.bn", "bn", dict(c=16)),
            ("dec2.relu", "relu", {}),
            ("head", "conv", dict(o=SEG_CLASSES, i=16, k=1, stride=1, pad=0)),
        ]
    )


def detmini_arch():
    return _seq(
        [
            ("bb1.conv", "conv", dict(o=16, i=3, k=3, stride=2, pad=1)),
            ("bb1.bn", "bn", dict(c=16)),
            ("bb1.relu", "relu", {}),
            ("bb2.conv", "conv", dict(o=32, i=16, k=3, stride=2, pad=1)),
            ("bb2.bn", "bn", dict(c=32)),
            ("bb2.relu", "relu", {}),
            ("bb3.conv", "conv", dict(o=64, i=32, k=3, stride=2, pad=1)),
            ("bb3.bn", "bn", dict(c=64)),
            ("bb3.relu", "relu", {}),
            ("neck.conv", "conv", dict(o=64, i=64, k=3, stride=1, pad=1)),
            ("neck.bn", "bn", dict(c=64)),
            ("neck.relu", "relu", {}),
            ("head", "conv", dict(o=5 + DET_CLASSES, i=64, k=1, stride=1, pad=0)),
        ]
    )


def speechmini_arch():
    h = LSTM_HIDDEN
    return [
        Node("lstm.fwd", "lstm", ["x"], dict(hidden=h, feats=SPEECH_FEATS, reverse=False)),
        Node("lstm.bwd", "lstm", ["x"], dict(hidden=h, feats=SPEECH_FEATS, reverse=True)),
        Node("concat", "concat", [0, 1], dict(axis=2)),
        Node("fc", "linear", [2], dict(o=SPEECH_TOKENS, i=2 * h)),
    ]


ARCHS = {
    "mobimini": mobimini_arch,
    "resmini": resmini_arch,
    "segmini": segmini_arch,
    "detmini": detmini_arch,
    "speechmini": speechmini_arch,
}

INPUT_SHAPES = {
    "mobimini": (3, 32, 32),
    "resmini": (3, 32, 32),
    "segmini": (3, 32, 32),
    "detmini": (3, 64, 64),
    "speechmini": (SPEECH_T, SPEECH_FEATS),
}


def param_specs(model):
    """[(name, shape)] in the Rust graph_param_tensors order."""
    specs = []
    for node in ARCHS[model]():
        c = node.cfg
        if node.kind == "conv":
            specs += [
                (f"{node.name}.weight", (c["o"], c["i"], c["k"], c["k"])),
                (f"{node.name}.bias", (c["o"],)),
            ]
        elif node.kind == "dwconv":
            specs += [
                (f"{node.name}.weight", (c["c"], 1, c["k"], c["k"])),
                (f"{node.name}.bias", (c["c"],)),
            ]
        elif node.kind == "linear":
            specs += [
                (f"{node.name}.weight", (c["o"], c["i"])),
                (f"{node.name}.bias", (c["o"],)),
            ]
        elif node.kind == "bn":
            specs += [
                (f"{node.name}.{p}", (c["c"],)) for p in ("gamma", "beta", "mean", "var")
            ]
        elif node.kind == "lstm":
            h, f = c["hidden"], c["feats"]
            specs += [
                (f"{node.name}.w_ih", (4 * h, f)),
                (f"{node.name}.w_hh", (4 * h, h)),
                (f"{node.name}.bias", (4 * h,)),
            ]
    return specs


# ---------------------------------------------------------------------
# Node evaluation.
# ---------------------------------------------------------------------


def _conv(x, w, b, stride, pad):
    y = lax.conv_general_dilated(
        x, w, (stride, stride), [(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return y + b.reshape(1, -1, 1, 1)


def _dwconv(x, w, b, stride, pad):
    c = x.shape[1]
    y = lax.conv_general_dilated(
        x, w, (stride, stride), [(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
        feature_group_count=c,
    )
    return y + b.reshape(1, -1, 1, 1)


def _lstm(x, w_ih, w_hh, bias, hidden, reverse):
    n, t, f = x.shape
    xp = (x.reshape(n * t, f) @ w_ih.T).reshape(n, t, 4 * hidden)
    xs = jnp.flip(xp, axis=1) if reverse else xp

    def step(carry, xt):
        h, c = carry
        a = xt + h @ w_hh.T + bias
        i, fg, g, o = jnp.split(a, 4, axis=-1)
        i, fg, o = jax.nn.sigmoid(i), jax.nn.sigmoid(fg), jax.nn.sigmoid(o)
        g = jnp.tanh(g)
        c = fg * c + i * g
        h = o * jnp.tanh(c)
        return (h, c), h

    init = (jnp.zeros((n, hidden)), jnp.zeros((n, hidden)))
    _, hs = lax.scan(step, init, jnp.transpose(xs, (1, 0, 2)))
    hs = jnp.transpose(hs, (1, 0, 2))  # [N, T, H]
    return jnp.flip(hs, axis=1) if reverse else hs


def eval_node(node, ins, params, weight_tf=None):
    """Evaluate one node. `params` is a dict name->array for this node's
    tensors; `weight_tf` optionally transforms the weight before use (the
    on_weight hook — quantsim's parameter quantizer)."""
    k, c = node.kind, node.cfg
    x = ins[0] if ins else None
    tf = weight_tf if weight_tf is not None else (lambda name, w: w)
    if k == "conv":
        return _conv(x, tf(node.name, params[f"{node.name}.weight"]),
                     params[f"{node.name}.bias"], c["stride"], c["pad"])
    if k == "dwconv":
        return _dwconv(x, tf(node.name, params[f"{node.name}.weight"]),
                       params[f"{node.name}.bias"], c["stride"], c["pad"])
    if k == "linear":
        w = tf(node.name, params[f"{node.name}.weight"])
        return x @ w.T + params[f"{node.name}.bias"]
    if k == "bn":
        g, b = params[f"{node.name}.gamma"], params[f"{node.name}.beta"]
        m, v = params[f"{node.name}.mean"], params[f"{node.name}.var"]
        shape = (1, -1) + (1,) * (x.ndim - 2)
        scale = (g / jnp.sqrt(v + 1e-5)).reshape(shape)
        shift = (b - m * g / jnp.sqrt(v + 1e-5)).reshape(shape)
        return x * scale + shift
    if k == "relu":
        return jax.nn.relu(x)
    if k == "relu6":
        return jnp.clip(x, 0.0, 6.0)
    if k == "maxpool2":
        return lax.reduce_window(x, -jnp.inf, lax.max, (1, 1, 2, 2), (1, 1, 2, 2), "VALID")
    if k == "avgpool2":
        s = lax.reduce_window(x, 0.0, lax.add, (1, 1, 2, 2), (1, 1, 2, 2), "VALID")
        return s / 4.0
    if k == "gap":
        return jnp.mean(x, axis=(2, 3))
    if k == "upsample2":
        return jnp.repeat(jnp.repeat(x, 2, axis=2), 2, axis=3)
    if k == "add":
        return sum(ins[1:], ins[0])
    if k == "concat":
        return jnp.concatenate(ins, axis=c["axis"])
    if k == "flatten":
        return x.reshape(x.shape[0], -1)
    if k == "lstm":
        return _lstm(
            x,
            tf(node.name, params[f"{node.name}.w_ih"]),
            params[f"{node.name}.w_hh"],
            params[f"{node.name}.bias"],
            c["hidden"],
            c["reverse"],
        )
    raise ValueError(f"unknown node kind {k}")


def params_dict(model, flat):
    """Zip a flat parameter list into a name->array dict."""
    specs = param_specs(model)
    assert len(flat) == len(specs), (len(flat), len(specs))
    return {name: p for (name, _), p in zip(specs, flat)}


def forward(model, flat_params, x, weight_tf=None, output_tf=None):
    """FP32 forward of `model`. `weight_tf(name, w)` / `output_tf(name, y)`
    are the quantsim hook points (identity by default)."""
    arch = ARCHS[model]()
    params = params_dict(model, flat_params)
    otf = output_tf if output_tf is not None else (lambda name, y: y)
    acts = []
    for node in arch:
        ins = [x if i == "x" else acts[i] for i in node.inputs]
        y = eval_node(node, ins, params, weight_tf)
        acts.append(otf(node.name, y))
    return acts[-1]


def forward_train(model, flat_params, x):
    """Training-mode forward: BatchNorm nodes normalize with *batch*
    statistics (differentiated through, like framework BN in train mode).
    Returns (logits, {bn_name: (batch_mean, batch_var)}) so the train step
    can update the running statistics — mirrors the Rust engine's
    `Graph::forward_train`."""
    arch = ARCHS[model]()
    params = params_dict(model, flat_params)
    acts = []
    stats = {}
    for node in arch:
        ins = [x if i == "x" else acts[i] for i in node.inputs]
        if node.kind == "bn":
            xin = ins[0]
            axes = tuple(i for i in range(xin.ndim) if i != 1)
            mu = jnp.mean(xin, axis=axes)
            var = jnp.mean((xin - mu.reshape((1, -1) + (1,) * (xin.ndim - 2))) ** 2, axis=axes)
            stats[node.name] = (mu, var)
            g, b = params[f"{node.name}.gamma"], params[f"{node.name}.beta"]
            shape = (1, -1) + (1,) * (xin.ndim - 2)
            y = (xin - mu.reshape(shape)) / jnp.sqrt(var.reshape(shape) + 1e-5)
            y = y * g.reshape(shape) + b.reshape(shape)
        else:
            y = eval_node(node, ins, params)
        acts.append(y)
    return acts[-1], stats


# ---------------------------------------------------------------------
# Quantsim forward (fig 3.1 placement under the default runtime config).
# ---------------------------------------------------------------------

# Ops that do not requantize their output (§7.3.1 / Op::requantizes_output).
NO_REQUANT = {"flatten", "maxpool2"}
WEIGHTED = {"conv", "dwconv", "linear", "lstm"}
# Default-config supergroups: the weighted/BN outputs inside fused chains
# carry no activation quantizer; the trailing activation does.
FUSE_HEADS = {"conv", "dwconv", "linear"}
FUSE_TAILS = {"bn", "relu", "relu6"}


def act_slots(model):
    """Node names that carry an activation quantizer under the default
    config (mirrors quantsim::config::supergroup_suppressed)."""
    arch = ARCHS[model]()
    consumers = {i: [] for i in range(len(arch))}
    for j, node in enumerate(arch):
        for i in node.inputs:
            if i != "x":
                consumers[i].append(j)
    suppressed = set()
    for i, node in enumerate(arch):
        if node.kind in FUSE_HEADS or node.kind == "bn":
            cons = consumers[i]
            if len(cons) == 1 and arch[cons[0]].kind in FUSE_TAILS:
                suppressed.add(i)
    return [
        n.name
        for i, n in enumerate(arch)
        if n.kind not in NO_REQUANT and i not in suppressed
    ]


def param_slots(model):
    """Weighted-layer names (parameter quantizers), in node order."""
    return [n.name for n in ARCHS[model]() if n.kind in WEIGHTED]


def qsim_forward(model, flat_params, x, act_enc, param_enc, act_bw=8, param_bw=8):
    """Quantized-sim forward: per-tensor asymmetric activations, symmetric
    signed weights — the default-config placement of chapter 3, with the
    qdq ops running through the L1 Pallas fake-quant kernel.

    act_enc [n_act + 1, 2]: (scale, zero_point) rows — row 0 is the model
    input quantizer, then one per act slot in node order. param_enc
    [n_param, 2]: (scale, 0) rows in weighted-node order.
    """
    a_names = act_slots(model)
    p_names = param_slots(model)
    a_idx = {n: i + 1 for i, n in enumerate(a_names)}
    p_idx = {n: i for i, n in enumerate(p_names)}
    a_lo, a_hi = 0.0, float(2**act_bw - 1)
    half = float(2 ** (param_bw - 1) - 1)

    def weight_tf(name, w):
        s = param_enc[p_idx[name], 0]
        return fake_quant(w, s, 0.0, int_min=-half, int_max=half)

    def output_tf(name, y):
        if name not in a_idx:
            return y
        row = a_idx[name]
        return fake_quant(y, act_enc[row, 0], act_enc[row, 1], int_min=a_lo, int_max=a_hi)

    xq = fake_quant(x, act_enc[0, 0], act_enc[0, 1], int_min=a_lo, int_max=a_hi)
    return forward(model, flat_params, xq, weight_tf=weight_tf, output_tf=output_tf)


# ---------------------------------------------------------------------
# Training steps (SGD in-graph; lowered once, driven from Rust).
# ---------------------------------------------------------------------


def ce_loss(model, flat_params, x, y_onehot):
    logits = forward(model, flat_params, x)
    logz = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.sum(y_onehot * logz, axis=-1))


def fp32_step(model, flat_params, x, y_onehot, lr):
    """One FP32 SGD step with training-mode BN: returns
    (new_params..., loss). BatchNorm layers normalize with batch stats
    (exact BN gradient via autodiff) and their running mean/var parameters
    receive the 0.9-EMA update, exactly like the Rust trainer."""

    def loss_fn(params):
        logits, stats = forward_train(model, params, x)
        logz = jax.nn.log_softmax(logits, axis=-1)
        return -jnp.mean(jnp.sum(y_onehot * logz, axis=-1)), stats

    (loss, stats), grads = jax.value_and_grad(loss_fn, has_aux=True)(list(flat_params))
    new = [p - lr * g for p, g in zip(flat_params, grads)]
    # Running-stat EMA: overwrite the (gradient-free) mean/var params.
    for i, (name, _) in enumerate(param_specs(model)):
        for suffix, k in ((".mean", 0), (".var", 1)):
            if name.endswith(suffix):
                bn = name[: -len(suffix)]
                if bn in stats:
                    new[i] = 0.9 * flat_params[i] + 0.1 * stats[bn][k]
    return (*new, loss)


def _make_ste(int_min, int_max):
    """STE-wrapped Pallas fake-quant (fig 5.1): the custom VJP passes the
    upstream gradient straight through the quantizer (Bengio et al. 2013)
    and — crucially — keeps jax.grad from trying to linearize through the
    pallas_call interior, which interpret-mode kernels do not support."""

    @jax.custom_vjp
    def ste(v, s, z):
        return fake_quant(v, s, z, int_min=int_min, int_max=int_max)

    def fwd(v, s, z):
        return ste(v, s, z), None

    def bwd(_res, g):
        return (g, jnp.zeros(()), jnp.zeros(()))

    ste.defvjp(fwd, bwd)
    return ste


_ste_act8 = _make_ste(0.0, 255.0)
_ste_w8 = _make_ste(-127.0, 127.0)


def qat_ce_loss(model, flat_params, x, y_onehot, act_enc, param_enc):
    """Fake-quant CE loss with STE (fig 5.1): forward through qdq, backward
    skips the quantizers via the custom straight-through VJP."""
    a_names = act_slots(model)
    p_names = param_slots(model)
    a_idx = {n: i + 1 for i, n in enumerate(a_names)}
    p_idx = {n: i for i, n in enumerate(p_names)}

    def weight_tf(name, w):
        return _ste_w8(w, param_enc[p_idx[name], 0], jnp.zeros(()))

    def output_tf(name, y):
        if name not in a_idx:
            return y
        r = a_idx[name]
        return _ste_act8(y, act_enc[r, 0], act_enc[r, 1])

    xq = _ste_act8(x, act_enc[0, 0], act_enc[0, 1])
    logits = forward(model, flat_params, xq, weight_tf=weight_tf, output_tf=output_tf)
    logz = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.sum(y_onehot * logz, axis=-1))


def qat_step(model, flat_params, x, y_onehot, act_enc, param_enc, lr):
    """One QAT STE SGD step: returns (new_params..., loss)."""
    loss, grads = jax.value_and_grad(qat_ce_loss, argnums=1)(
        model, flat_params, x, y_onehot, act_enc, param_enc
    )
    new = [p - lr * g for p, g in zip(flat_params, grads)]
    return (*new, loss)
