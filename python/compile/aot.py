"""AOT compile path: lower every L2 program to HLO text + manifest.

`make artifacts` runs this once. Each program is jitted, lowered to
stablehlo, converted to an XlaComputation, and dumped as **HLO text**
(NOT `lowered.compiler_ir("hlo")`-proto or `.serialize()`: jax >= 0.5
emits HloModuleProtos with 64-bit instruction ids which the rust side's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly — see /opt/xla-example/README.md).

The manifest records every program's input/output shapes so the Rust
runtime (`rust/src/runtime.rs`) can validate tensors before dispatch.
Python never runs after this script exits.

Usage: python -m compile.aot --out ../artifacts
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels.qmatmul import qmatmul
from .kernels.range_stats import range_stats

FWD_BATCH = 8
STEP_BATCH = 16


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


def param_structs(name):
    return [f32(s) for _, s in model.param_specs(name)]


def programs():
    """(name, fn returning a tuple, example_args, description) table."""
    progs = []

    # Forward passes for every zoo model.
    for m in model.ARCHS:
        x = f32((FWD_BATCH,) + model.INPUT_SHAPES[m])

        def fwd(params_and_x, m=m):
            *params, xv = params_and_x
            return (model.forward(m, params, xv),)

        progs.append(
            (
                f"{m}_fwd",
                lambda *a, m=m: (model.forward(m, list(a[:-1]), a[-1]),),
                param_structs(m) + [x],
                f"FP32 forward of {m} (batch {FWD_BATCH})",
            )
        )

    # Quantsim forward for the cross-engine check (mobimini, default config).
    m = "mobimini"
    n_act = len(model.act_slots(m)) + 1
    n_param = len(model.param_slots(m))
    progs.append(
        (
            "mobimini_qsim_fwd",
            lambda *a: (
                model.qsim_forward(m, list(a[:-3]), a[-3], a[-2], a[-1]),
            ),
            param_structs(m)
            + [f32((FWD_BATCH,) + model.INPUT_SHAPES[m]), f32((n_act, 2)), f32((n_param, 2))],
            "Quantsim forward of mobimini via the Pallas fake-quant kernel "
            "(default config placement; act/param encodings as inputs)",
        )
    )

    # Training steps (FP32 SGD + QAT STE) for the classifiers.
    for m in ("mobimini", "resmini"):
        x = f32((STEP_BATCH,) + model.INPUT_SHAPES[m])
        y = f32((STEP_BATCH, model.CLS_CLASSES))
        lr = f32(())
        progs.append(
            (
                f"{m}_fp32_step",
                lambda *a, m=m: model.fp32_step(m, list(a[:-3]), a[-3], a[-2], a[-1]),
                param_structs(m) + [x, y, lr],
                f"One FP32 SGD step of {m}: (params..., x, y_onehot, lr) -> "
                "(params'..., loss)",
            )
        )
    m = "mobimini"
    progs.append(
        (
            "mobimini_qat_step",
            lambda *a: model.qat_step(
                m, list(a[:-5]), a[-5], a[-4], a[-3], a[-2], a[-1]
            ),
            param_structs(m)
            + [
                f32((STEP_BATCH,) + model.INPUT_SHAPES[m]),
                f32((STEP_BATCH, model.CLS_CLASSES)),
                f32((n_act, 2)),
                f32((n_param, 2)),
                f32(()),
            ],
            "One QAT STE step of mobimini (fig 5.1): fake-quant forward, "
            "straight-through backward",
        )
    )

    # Standalone kernel demos (fig 2.2 MAC pipeline, range observation).
    progs.append(
        (
            "qmatmul_demo",
            lambda x, w, b, s: (
                qmatmul(x, w, b, s[0], s[1], s[2], s[3]),
            ),
            [f32((128, 256)), f32((256, 128)), f32((128,)), f32((4,))],
            "Quantized 128x256x128 matmul + requantize via the Pallas "
            "qmatmul kernel (INT8 grids as f32)",
        )
    )
    progs.append(
        (
            "range_stats_demo",
            lambda x: (range_stats(x),),
            [f32((STEP_BATCH, 3, 32, 32))],
            "Per-tensor (min, max) via the Pallas streaming reduction",
        )
    )
    return progs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None, help="lower a single program")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {"programs": {}}
    for name, fn, example_args, desc in programs():
        if args.only and name != args.only:
            continue
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(text)
        # Output shapes from the jitted function's abstract eval.
        out_shapes = [
            list(o.shape) for o in jax.eval_shape(fn, *example_args)
        ]
        manifest["programs"][name] = {
            "file": fname,
            "inputs": [list(a.shape) for a in example_args],
            "outputs": out_shapes,
            "desc": desc,
        }
        print(f"lowered {name:<24} ({len(text) / 1024:.0f} KiB)")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest with {len(manifest['programs'])} programs to {args.out}")


if __name__ == "__main__":
    main()
