"""L2 JAX model checks: shapes, quantizer placement, train-step behavior,
and quantsim-vs-oracle composition."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref

EXPECTED_OUT = {
    "mobimini": (2, 10),
    "resmini": (2, 10),
    "segmini": (2, 6, 32, 32),
    "detmini": (2, 9, 8, 8),
    "speechmini": (2, 20, 6),
}


def make_params(m, seed=0, scale=0.1):
    rng = np.random.default_rng(seed)
    params = []
    for name, shape in model.param_specs(m):
        if name.endswith(".var"):
            params.append(jnp.array(rng.uniform(0.5, 1.5, shape).astype(np.float32)))
        elif name.endswith(".gamma"):
            params.append(jnp.ones(shape, jnp.float32))
        else:
            params.append(jnp.array((rng.standard_normal(shape) * scale).astype(np.float32)))
    return params


@pytest.mark.parametrize("m", list(model.ARCHS))
def test_forward_shapes(m):
    params = make_params(m)
    x = jnp.array(np.random.default_rng(1).standard_normal((2,) + model.INPUT_SHAPES[m]), jnp.float32)
    y = model.forward(m, params, x)
    assert y.shape == EXPECTED_OUT[m]
    assert bool(jnp.all(jnp.isfinite(y)))


def test_quantizer_placement_matches_rust_counts():
    # rust/src/quantsim tests pin (acts=10, params=8) for mobimini under
    # the default config; the JAX mirror must agree (cross-engine contract).
    assert len(model.act_slots("mobimini")) + 1 == 10
    assert len(model.param_slots("mobimini")) == 8


def test_act_slots_skip_fused_and_no_requant_ops():
    slots = set(model.act_slots("mobimini"))
    assert "stem.conv" not in slots  # fused into conv+bn+relu6 supergroup
    assert "stem.bn" not in slots
    assert "stem.relu6" in slots
    assert "gap" in slots
    assert "fc" in slots


def test_qsim_forward_equals_oracle_composition():
    m = "mobimini"
    params = make_params(m, seed=2)
    x = jnp.array(np.random.default_rng(3).standard_normal((2,) + model.INPUT_SHAPES[m]), jnp.float32)
    n_act = len(model.act_slots(m)) + 1
    n_par = len(model.param_slots(m))
    rng = np.random.default_rng(4)
    act_enc = jnp.array(
        np.stack(
            [rng.uniform(0.01, 0.1, n_act), rng.integers(0, 255, n_act).astype(float)],
            axis=1,
        ),
        jnp.float32,
    )
    par_enc = jnp.array(
        np.stack([rng.uniform(0.001, 0.05, n_par), np.zeros(n_par)], axis=1), jnp.float32
    )
    got = model.qsim_forward(m, params, x, act_enc, par_enc)

    # Oracle: same placement, ref fake-quant instead of the Pallas kernel.
    a_idx = {n: i + 1 for i, n in enumerate(model.act_slots(m))}
    p_idx = {n: i for i, n in enumerate(model.param_slots(m))}

    def wtf(name, w):
        return ref.fake_quant_ref(w, par_enc[p_idx[name], 0], 0.0, -127.0, 127.0)

    def otf(name, y):
        if name not in a_idx:
            return y
        r = a_idx[name]
        return ref.fake_quant_ref(y, act_enc[r, 0], act_enc[r, 1], 0.0, 255.0)

    xq = ref.fake_quant_ref(x, act_enc[0, 0], act_enc[0, 1], 0.0, 255.0)
    want = model.forward(m, params, xq, weight_tf=wtf, output_tf=otf)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_qsim_differs_from_fp32_but_tracks_it():
    m = "mobimini"
    params = make_params(m, seed=5)
    x = jnp.array(np.random.default_rng(6).standard_normal((2,) + model.INPUT_SHAPES[m]), jnp.float32)
    fp = model.forward(m, params, x)
    n_act = len(model.act_slots(m)) + 1
    n_par = len(model.param_slots(m))
    # Generous 8-bit encodings around the actual ranges.
    act_enc = jnp.tile(jnp.array([[0.05, 128.0]], jnp.float32), (n_act, 1))
    par_enc = jnp.tile(jnp.array([[0.005, 0.0]], jnp.float32), (n_par, 1))
    q = model.qsim_forward(m, params, x, act_enc, par_enc)
    diff = float(jnp.max(jnp.abs(q - fp)))
    assert diff > 0.0
    assert diff < 5.0 * float(jnp.max(jnp.abs(fp)) + 1.0)


def _one_hot(labels, k):
    return jnp.eye(k, dtype=jnp.float32)[labels]


def test_fp32_step_reduces_loss():
    m = "mobimini"
    params = make_params(m, seed=7)
    rng = np.random.default_rng(8)
    x = jnp.array(rng.standard_normal((8,) + model.INPUT_SHAPES[m]), jnp.float32)
    y = _one_hot(jnp.array(rng.integers(0, 10, 8)), 10)
    lr = jnp.float32(0.05)
    first = None
    for _ in range(10):
        *params, loss = model.fp32_step(m, params, x, y, lr)
        if first is None:
            first = float(loss)
    assert float(loss) < first, f"loss did not fall: {first} -> {float(loss)}"


def test_qat_step_reduces_loss_and_moves_weights():
    m = "mobimini"
    params = make_params(m, seed=9)
    rng = np.random.default_rng(10)
    x = jnp.array(rng.standard_normal((8,) + model.INPUT_SHAPES[m]), jnp.float32)
    y = _one_hot(jnp.array(rng.integers(0, 10, 8)), 10)
    n_act = len(model.act_slots(m)) + 1
    n_par = len(model.param_slots(m))
    act_enc = jnp.tile(jnp.array([[0.05, 128.0]], jnp.float32), (n_act, 1))
    par_enc = jnp.tile(jnp.array([[0.005, 0.0]], jnp.float32), (n_par, 1))
    w0 = params[0]
    first = None
    for _ in range(8):
        *params, loss = model.qat_step(m, params, x, y, act_enc, par_enc, jnp.float32(0.05))
        if first is None:
            first = float(loss)
    assert float(loss) < first
    assert float(jnp.max(jnp.abs(params[0] - w0))) > 0.0


def test_param_specs_order_is_stable():
    specs = model.param_specs("speechmini")
    names = [n for n, _ in specs]
    assert names == [
        "lstm.fwd.w_ih", "lstm.fwd.w_hh", "lstm.fwd.bias",
        "lstm.bwd.w_ih", "lstm.bwd.w_hh", "lstm.bwd.bias",
        "fc.weight", "fc.bias",
    ]


def test_lstm_reverse_differs_and_is_time_aligned():
    h, f, t = 4, 3, 6
    rng = np.random.default_rng(11)
    x = jnp.array(rng.standard_normal((2, t, f)), jnp.float32)
    w_ih = jnp.array(rng.standard_normal((4 * h, f)) * 0.3, jnp.float32)
    w_hh = jnp.array(rng.standard_normal((4 * h, h)) * 0.3, jnp.float32)
    b = jnp.zeros(4 * h, jnp.float32)
    fwd = model._lstm(x, w_ih, w_hh, b, h, False)
    bwd = model._lstm(x, w_ih, w_hh, b, h, True)
    assert fwd.shape == (2, t, h)
    assert float(jnp.max(jnp.abs(fwd - bwd))) > 0.0
    # Reversed input through forward LSTM == flipped reverse LSTM output.
    fwd_of_flipped = model._lstm(jnp.flip(x, 1), w_ih, w_hh, b, h, False)
    np.testing.assert_allclose(jnp.flip(bwd, 1), fwd_of_flipped, rtol=1e-5, atol=1e-6)
