"""AOT export smoke tests: HLO text round-trips through the interchange
format the Rust runtime consumes."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model


def test_to_hlo_text_produces_parseable_module():
    def fn(x, y):
        return (jnp.matmul(x, y) + 2.0,)

    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    text = aot.to_hlo_text(jax.jit(fn).lower(spec, spec))
    assert text.startswith("HloModule")
    assert "parameter(0)" in text
    assert "ROOT" in text


def test_program_table_covers_every_model_and_kernel():
    names = {name for name, *_ in aot.programs()}
    for m in model.ARCHS:
        assert f"{m}_fwd" in names
    assert {"mobimini_qsim_fwd", "mobimini_fp32_step", "mobimini_qat_step",
            "qmatmul_demo", "range_stats_demo"} <= names


def test_manifest_matches_program_shapes(tmp_path):
    # Lower one small program end-to-end and check the manifest entry.
    import sys
    from unittest import mock

    argv = ["aot", "--out", str(tmp_path), "--only", "range_stats_demo"]
    with mock.patch.object(sys, "argv", argv):
        aot.main()
    manifest = json.load(open(tmp_path / "manifest.json"))
    entry = manifest["programs"]["range_stats_demo"]
    assert entry["inputs"] == [[aot.STEP_BATCH, 3, 32, 32]]
    assert entry["outputs"] == [[2]]
    text = open(tmp_path / entry["file"]).read()
    assert text.startswith("HloModule")


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
def test_built_manifest_is_complete():
    path = os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")
    manifest = json.load(open(path))
    progs = manifest["programs"]
    assert len(progs) >= 11
    for name, entry in progs.items():
        assert os.path.exists(os.path.join(os.path.dirname(path), entry["file"])), name
        assert entry["inputs"] and entry["outputs"], name
