"""L1 Pallas kernels vs pure-jnp oracles — the core correctness signal.

hypothesis sweeps shapes and quantization parameters; every kernel must
match its `ref.py` oracle bit-for-bit (same jnp ops, same order) or to
float tolerance where accumulation order differs.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.fake_quant import fake_quant, fake_quant_per_channel
from compile.kernels.qmatmul import qmatmul
from compile.kernels.range_stats import range_stats
from compile.kernels import ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def rand(shape, scale=2.0, seed=0):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(shape) * scale).astype(np.float32)


# ---------------------------------------------------------------------
# fake_quant
# ---------------------------------------------------------------------


@given(
    m=st.integers(1, 70),
    n=st.integers(1, 70),
    bw=st.sampled_from([2, 4, 8]),
    scale=st.floats(1e-3, 1.0),
    seed=st.integers(0, 2**16),
)
def test_fake_quant_matches_ref_asymmetric(m, n, bw, scale, seed):
    x = rand((m, n), seed=seed)
    zp = float((2**bw - 1) // 2)
    got = fake_quant(jnp.array(x), scale, zp, int_min=0, int_max=2**bw - 1)
    want = ref.fake_quant_ref(jnp.array(x), scale, zp, 0, 2**bw - 1)
    np.testing.assert_allclose(got, want, rtol=0, atol=0)


@given(
    rank=st.integers(1, 4),
    bw=st.sampled_from([4, 8]),
    seed=st.integers(0, 2**16),
)
def test_fake_quant_arbitrary_rank_symmetric(rank, bw, seed):
    rng = np.random.default_rng(seed)
    shape = tuple(rng.integers(1, 9, size=rank))
    x = rand(shape, seed=seed + 1)
    half = float(2 ** (bw - 1) - 1)
    got = fake_quant(jnp.array(x), 0.1, 0.0, int_min=-half, int_max=half)
    want = ref.fake_quant_ref(jnp.array(x), 0.1, 0.0, -half, half)
    np.testing.assert_allclose(got, want, rtol=0, atol=0)
    assert got.shape == x.shape


def test_fake_quant_grid_points_are_fixpoints():
    # Values already on the grid must survive qdq exactly (eq 2.7).
    s, z = 0.25, 8.0
    grid = (np.arange(0, 16) - z) * s
    got = fake_quant(jnp.array(grid, jnp.float32), s, z, int_min=0, int_max=15)
    np.testing.assert_allclose(got, grid, atol=0)


def test_fake_quant_clips_out_of_range():
    got = fake_quant(jnp.array([1e6, -1e6], jnp.float32), 0.1, 0.0, int_min=-127, int_max=127)
    np.testing.assert_allclose(got, [12.7, -12.7], rtol=1e-6)


@given(
    c=st.integers(1, 20),
    n=st.integers(1, 40),
    seed=st.integers(0, 2**16),
)
def test_fake_quant_per_channel_matches_ref(c, n, seed):
    x = rand((c, n), seed=seed)
    rng = np.random.default_rng(seed + 7)
    scales = rng.uniform(0.01, 0.5, size=c).astype(np.float32)
    zps = rng.integers(0, 255, size=c).astype(np.float32)
    got = fake_quant_per_channel(
        jnp.array(x), jnp.array(scales), jnp.array(zps), int_min=0, int_max=255
    )
    want = ref.fake_quant_ref(
        jnp.array(x), scales.reshape(-1, 1), zps.reshape(-1, 1), 0, 255
    )
    np.testing.assert_allclose(got, want, rtol=0, atol=0)


def test_per_channel_channels_independent():
    # Channel 0 tiny scale, channel 1 huge: quantizing ch1 must not move ch0.
    x = np.array([[0.5, -0.5], [50.0, -50.0]], np.float32)
    got = fake_quant_per_channel(
        jnp.array(x),
        jnp.array([1 / 254, 100 / 127], np.float32),
        jnp.array([127.0, 0.0], np.float32),
        int_min=0,
        int_max=255,
    )
    np.testing.assert_allclose(got[0], x[0], atol=1e-2)


# ---------------------------------------------------------------------
# qmatmul
# ---------------------------------------------------------------------


@given(
    m=st.integers(1, 40),
    k=st.integers(1, 40),
    n=st.integers(1, 40),
    seed=st.integers(0, 2**16),
)
def test_qmatmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 256, size=(m, k)).astype(np.float32)
    w = rng.integers(-127, 128, size=(k, n)).astype(np.float32)
    b = rng.integers(-1000, 1000, size=(n,)).astype(np.float32)
    s_x, s_w, s_y, z_y = 0.02, 0.01, 0.05, 128.0
    got = qmatmul(jnp.array(x), jnp.array(w), jnp.array(b), s_x, s_w, s_y, z_y)
    want = ref.qmatmul_ref(jnp.array(x), jnp.array(w), jnp.array(b), s_x, s_w, s_y, z_y)
    np.testing.assert_allclose(got, want, atol=1.0)  # +/- 1 int on round ties
    # Output must be on the INT8 grid.
    assert float(got.min()) >= 0.0 and float(got.max()) <= 255.0
    np.testing.assert_allclose(got, jnp.round(got), atol=0)


def test_qmatmul_integer_exactness():
    # Accumulation of integer products is exact (INT32-sim in f32): a
    # known-product case must match exactly, not approximately.
    x = jnp.full((4, 8), 255.0)
    w = jnp.full((8, 4), 127.0)
    b = jnp.zeros(4)
    got = qmatmul(x, w, b, 1.0, 1.0, 255.0 * 127.0 * 8.0, 0.0)
    np.testing.assert_allclose(got, jnp.ones((4, 4)), atol=0)


# ---------------------------------------------------------------------
# range_stats
# ---------------------------------------------------------------------


@given(
    n=st.integers(1, 5000),
    seed=st.integers(0, 2**16),
)
def test_range_stats_matches_ref(n, seed):
    x = rand((n,), seed=seed)
    got = range_stats(jnp.array(x))
    want = ref.range_stats_ref(jnp.array(x))
    np.testing.assert_allclose(got, want, atol=0)


def test_range_stats_multiblock_and_rank():
    x = rand((3, 7, 41), seed=3)  # padded, multi-tile path
    got = range_stats(jnp.array(x))
    assert got[0] == pytest.approx(x.min())
    assert got[1] == pytest.approx(x.max())
